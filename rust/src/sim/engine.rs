//! Core event loop: a min-heap of timestamped events dispatched in order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in nanoseconds.
pub type SimTime = f64;

/// What an event does when it fires (interpreted by the driver).
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Transaction `id` arrives at hop `hop` of its path.
    Arrive { id: usize, hop: usize },
    /// Transaction `id` finishes service at hop `hop`.
    Depart { id: usize, hop: usize },
    /// Transaction `id` completes end-to-end.
    Complete { id: usize },
    /// Driver-defined.
    Custom { tag: u64 },
}

#[derive(Clone, Debug)]
struct Event {
    at: SimTime,
    seq: u64, // tie-break: FIFO among simultaneous events
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue + clock.
#[derive(Debug, Default)]
pub struct Engine {
    heap: BinaryHeap<Event>,
    now: SimTime,
    seq: u64,
    dispatched: u64,
}

impl Engine {
    pub fn new() -> Engine {
        Engine::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events dispatched so far.
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Schedule `kind` at absolute time `at` (>= now).
    pub fn schedule(&mut self, at: SimTime, kind: EventKind) {
        debug_assert!(at >= self.now, "schedule into the past: {at} < {}", self.now);
        self.seq += 1;
        self.heap.push(Event { at, seq: self.seq, kind });
    }

    /// Schedule `kind` after a delay.
    pub fn after(&mut self, delay: SimTime, kind: EventKind) {
        self.schedule(self.now + delay, kind);
    }

    /// Pop the next event, advancing the clock. None when drained.
    pub fn next(&mut self) -> Option<(SimTime, EventKind)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now);
        self.now = ev.at;
        self.dispatched += 1;
        Some((ev.at, ev.kind))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut e = Engine::new();
        e.schedule(30.0, EventKind::Custom { tag: 3 });
        e.schedule(10.0, EventKind::Custom { tag: 1 });
        e.schedule(20.0, EventKind::Custom { tag: 2 });
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = e.next() {
            tags.push(tag);
        }
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(e.now(), 30.0);
        assert_eq!(e.dispatched(), 3);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut e = Engine::new();
        for tag in 0..100 {
            e.schedule(5.0, EventKind::Custom { tag });
        }
        let mut last = None;
        while let Some((_, EventKind::Custom { tag })) = e.next() {
            if let Some(l) = last {
                assert!(tag > l, "FIFO violated: {tag} after {l}");
            }
            last = Some(tag);
        }
    }

    #[test]
    fn after_is_relative_to_now() {
        let mut e = Engine::new();
        e.schedule(100.0, EventKind::Custom { tag: 0 });
        e.next();
        e.after(50.0, EventKind::Custom { tag: 1 });
        let (at, _) = e.next().unwrap();
        assert_eq!(at, 150.0);
    }

    #[test]
    fn clock_monotone() {
        let mut e = Engine::new();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..1000 {
            e.schedule(rng.f64() * 1e6, EventKind::Custom { tag: 0 });
        }
        let mut last = 0.0;
        while let Some((at, _)) = e.next() {
            assert!(at >= last);
            last = at;
        }
    }
}
