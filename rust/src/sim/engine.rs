//! Core event loop: a calendar-queue (timing-wheel) scheduler dispatching
//! timestamped events in order.
//!
//! # Performance architecture (§Perf)
//!
//! The scheduler is a **bucketed calendar queue** (Brown '88): virtual
//! bucket `floor(at / width)` maps onto a power-of-two wheel of sorted
//! mini-queues, so `schedule` is a bucket append (plus a short sorted
//! insert when arrivals land out of order inside one bucket) and `next`
//! is a pop from the front of the current bucket — O(1) amortized against
//! the binary heap's O(log n) sift, and without moving payloads: the
//! wheel carries lean `(time, seq, u32 handle)` keys while event payloads
//! sit in a slot slab recycled through a free list (the slab's high-water
//! mark equals peak *concurrently pending* events, not total scheduled).
//!
//! The payload slab is **structure-of-arrays**: instead of a
//! `Vec<EventKind>` of padded 24-byte enum values, three parallel columns
//! (`tags: Vec<u8>`, `w0/w1: Vec<u64>`) hold the discriminant and the two
//! payload words. [`EventKind`] stays the public API — `schedule` encodes
//! and `next` decodes at the slab boundary — but a slot costs 17 bytes
//! instead of 24 and the discriminant scan touches a dense byte column.
//! The reference heap keeps the plain `Vec<EventKind>` slab (it is the
//! oracle, not the optimized path).
//!
//! Far-future events (beyond one wheel rotation) park in an **overflow
//! list** and are refiled when the wheel drains into them. The wheel
//! **resizes on skew**: whenever occupancy outgrows the bucket count or a
//! rotation completes, the bucket width is recomputed from the live
//! event-time span (floored at the caller's granularity hint — for the
//! fabric simulator, the serialization-time quantum of the fastest link)
//! and every pending event is refiled. Dispatch order is byte-identical
//! to the reference binary heap kept in [`reference::HeapEngine`],
//! including FIFO `seq` tie-breaks at equal timestamps — pinned by
//! `calendar_queue_matches_heap_reference` in `tests/prop_invariants.rs`,
//! mirroring the PR-1 `SerialRouter` oracle pattern.

use std::cmp::Ordering;
use std::collections::VecDeque;

/// Simulation time in nanoseconds.
pub type SimTime = f64;

/// What an event does when it fires (interpreted by the driver).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Transaction `id` arrives at hop `hop` of its path.
    Arrive { id: usize, hop: usize },
    /// Transaction `id` completes end-to-end.
    Complete { id: usize },
    /// A service on link `link`, direction `dir` finished: the
    /// [`ClassedServer`](super::qos::ClassedServer) arbitrates its
    /// virtual channels and starts the next queued transaction. Only
    /// scheduled by queued-mode QoS policies — class-blind FCFS is
    /// time-released and never departs.
    Depart { link: u32, dir: u8 },
    /// Driver-defined.
    Custom { tag: u64 },
}

/// Pack an [`EventKind`] into the SoA slab's `(tag, w0, w1)` columns.
#[inline]
fn encode(kind: EventKind) -> (u8, u64, u64) {
    match kind {
        EventKind::Arrive { id, hop } => (0, id as u64, hop as u64),
        EventKind::Complete { id } => (1, id as u64, 0),
        EventKind::Depart { link, dir } => (2, link as u64, dir as u64),
        EventKind::Custom { tag } => (3, tag, 0),
    }
}

/// Inverse of [`encode`]; any tag outside 0..=2 decodes as `Custom`
/// (only `encode` writes tags, so the branch is exhaustive in practice).
#[inline]
fn decode(tag: u8, w0: u64, w1: u64) -> EventKind {
    match tag {
        0 => EventKind::Arrive { id: w0 as usize, hop: w1 as usize },
        1 => EventKind::Complete { id: w0 as usize },
        2 => EventKind::Depart { link: w0 as u32, dir: w1 as u8 },
        _ => EventKind::Custom { tag: w0 },
    }
}

/// Wheel key: ordering state only; the payload lives in the slab.
#[derive(Clone, Copy, Debug)]
struct CalEntry {
    at: SimTime,
    seq: u64, // tie-break: FIFO among simultaneous events
    slot: u32,
}

/// A full copy of an [`Engine`]'s queue state — wheel geometry, pending
/// entries, the SoA payload slab, clock, sequence counter and dispatch
/// count — taken by [`Engine::snapshot`] and applied back by
/// [`Engine::restore`]. The optimistic sharded backend checkpoints each
/// worker's engine at the epoch barrier and rolls the epoch back when a
/// late cross-shard reaction invalidates it; restoring `seq` and
/// `dispatched` alongside the queue keeps a replayed epoch's dispatch
/// order and event counts byte-identical to an epoch that was never
/// rolled back (pinned by `prop_checkpoint_restore_roundtrip`).
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    buckets: Vec<VecDeque<CalEntry>>,
    mask: u64,
    inv_width: f64,
    min_width: f64,
    cur_vb: u64,
    horizon_vb: u64,
    wheel_len: usize,
    overflow: Vec<CalEntry>,
    tags: Vec<u8>,
    w0: Vec<u64>,
    w1: Vec<u64>,
    free: Vec<u32>,
    now: SimTime,
    seq: u64,
    dispatched: u64,
}

impl CalEntry {
    /// Total order matching the reference heap: earliest time first,
    /// FIFO (`seq`) among equals. `at` is guaranteed finite by
    /// `schedule`, so `total_cmp` agrees with the numeric order.
    #[inline]
    fn cmp_key(&self, other: &CalEntry) -> Ordering {
        self.at.total_cmp(&other.at).then_with(|| self.seq.cmp(&other.seq))
    }
}

/// Smallest wheel; below this, bucket bookkeeping costs more than it saves.
const MIN_BUCKETS: usize = 64;
/// Largest wheel (bounds the memory of a skew-triggered grow).
const MAX_BUCKETS: usize = 1 << 17;

/// The event queue + clock.
#[derive(Debug)]
pub struct Engine {
    /// The wheel: virtual bucket `v` lives at `v & mask`, each bucket
    /// sorted ascending by `(at, seq)` so the front is the bucket minimum.
    buckets: Vec<VecDeque<CalEntry>>,
    mask: u64,
    /// Reciprocal of the bucket width (the hot path multiplies; the width
    /// itself is re-derived from the live event span on every rebuild).
    inv_width: f64,
    /// Floor for `width` on rebuilds: the caller's granularity hint.
    min_width: f64,
    /// Virtual bucket currently being drained (all pending wheel entries
    /// have a virtual bucket >= this).
    cur_vb: u64,
    /// First virtual bucket that files to `overflow` instead of the wheel.
    horizon_vb: u64,
    /// Entries currently on the wheel (excludes `overflow`).
    wheel_len: usize,
    /// Far-future events, unsorted; refiled when the wheel drains.
    overflow: Vec<CalEntry>,
    /// SoA payload slab: discriminant column plus two payload words per
    /// slot (see the module docs); slots recycle through `free`.
    tags: Vec<u8>,
    w0: Vec<u64>,
    w1: Vec<u64>,
    free: Vec<u32>,
    now: SimTime,
    seq: u64,
    dispatched: u64,
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::new()
    }
}

impl Engine {
    pub fn new() -> Engine {
        Engine::with_granularity(1.0)
    }

    /// Build an engine whose bucket width never shrinks below
    /// `granularity` ns — callers pass the finest meaningful event
    /// spacing (the fabric's serialization-time quantum) so dense bursts
    /// do not degenerate into per-event buckets. The width itself is
    /// re-derived from the live event distribution on every rebuild.
    pub fn with_granularity(granularity: f64) -> Engine {
        let min_width = if granularity.is_finite() && granularity > 1e-9 { granularity } else { 1e-9 };
        Engine {
            buckets: (0..MIN_BUCKETS).map(|_| VecDeque::new()).collect(),
            mask: MIN_BUCKETS as u64 - 1,
            inv_width: 1.0 / min_width,
            min_width,
            cur_vb: 0,
            horizon_vb: MIN_BUCKETS as u64,
            wheel_len: 0,
            overflow: Vec::new(),
            tags: Vec::new(),
            w0: Vec::new(),
            w1: Vec::new(),
            free: Vec::new(),
            now: 0.0,
            seq: 0,
            dispatched: 0,
        }
    }

    /// [`Engine::with_granularity`] plus a slab capacity hint: reserve
    /// the SoA payload columns for ~`slots` concurrently pending events
    /// up front. Sharded workers size this from their shard's link count
    /// so the slab never reallocates (and stays cache-resident) during
    /// epoch dispatch; the hint is only a reservation — the slab still
    /// grows on demand past it.
    pub fn with_granularity_and_capacity(granularity: f64, slots: usize) -> Engine {
        let mut e = Engine::with_granularity(granularity);
        e.tags.reserve(slots);
        e.w0.reserve(slots);
        e.w1.reserve(slots);
        e.free.reserve(slots);
        e
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events dispatched so far.
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    #[inline]
    fn vb_of(&self, at: SimTime) -> u64 {
        // truncation == floor for the non-negative times `schedule` allows
        (at * self.inv_width) as u64
    }

    /// Schedule `kind` at absolute time `at` (>= now). Panics on NaN or
    /// infinite timestamps (a non-finite key would silently corrupt the
    /// dispatch order) and on scheduling into the past — a real assert,
    /// not a debug one: a negative `after` delay in a release build would
    /// otherwise silently corrupt causality.
    pub fn schedule(&mut self, at: SimTime, kind: EventKind) {
        assert!(at.is_finite(), "non-finite event time {at}");
        assert!(at >= self.now, "schedule into the past: {at} < {}", self.now);
        self.seq += 1;
        let (tag, a, b) = encode(kind);
        let slot = match self.free.pop() {
            Some(s) => {
                let i = s as usize;
                self.tags[i] = tag;
                self.w0[i] = a;
                self.w1[i] = b;
                s
            }
            None => {
                self.tags.push(tag);
                self.w0.push(a);
                self.w1.push(b);
                (self.tags.len() - 1) as u32
            }
        };
        self.file(CalEntry { at, seq: self.seq, slot });
        // grow on skew: occupancy past ~2 entries/bucket means the sorted
        // per-bucket inserts start paying; refile at a data-derived width
        let nbuckets = self.buckets.len();
        if self.wheel_len + self.overflow.len() > 2 * nbuckets && nbuckets < MAX_BUCKETS {
            self.rebuild();
        }
    }

    /// File an entry into its wheel bucket (sorted ascending) or the
    /// overflow list, maintaining the `cur_vb` lower-bound invariant.
    fn file(&mut self, e: CalEntry) {
        let vb = self.vb_of(e.at);
        if vb >= self.horizon_vb {
            self.overflow.push(e);
            return;
        }
        if vb < self.cur_vb {
            // an insert behind the scan position (legal: the scan may have
            // advanced past empty buckets ahead of `now`) rewinds the scan
            self.cur_vb = vb;
        }
        let q = &mut self.buckets[(vb & self.mask) as usize];
        // common case: appended at the back (nondecreasing arrivals)
        if q.back().map(|b| b.cmp_key(&e) == Ordering::Less).unwrap_or(true) {
            q.push_back(e);
        } else {
            let pos = q.partition_point(|x| x.cmp_key(&e) == Ordering::Less);
            q.insert(pos, e);
        }
        self.wheel_len += 1;
    }

    /// Gather every pending entry, re-derive the wheel geometry from the
    /// live time distribution (~1 entry/bucket, width floored at the
    /// granularity hint), and refile — the resize-on-skew step, also the
    /// path that pulls the overflow list back in.
    fn rebuild(&mut self) {
        let mut all: Vec<CalEntry> = Vec::with_capacity(self.wheel_len + self.overflow.len());
        for q in &mut self.buckets {
            all.extend(q.drain(..));
        }
        all.append(&mut self.overflow);
        self.wheel_len = 0;
        if all.is_empty() {
            self.cur_vb = self.vb_of(self.now);
            self.horizon_vb = self.cur_vb.saturating_add(self.buckets.len() as u64);
            return;
        }
        let mut min_at = f64::INFINITY;
        let mut max_at = f64::NEG_INFINITY;
        for e in &all {
            min_at = min_at.min(e.at);
            max_at = max_at.max(e.at);
        }
        let n = all.len();
        let mut w = (max_at - min_at) / n as f64;
        if !w.is_finite() || w < self.min_width {
            w = self.min_width;
        }
        // keep virtual bucket indices well inside u64 range
        let w_floor = max_at / 1e15;
        if w < w_floor {
            w = w_floor;
        }
        let nb = n.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        if nb != self.buckets.len() {
            self.buckets.resize_with(nb, VecDeque::new);
        }
        self.mask = nb as u64 - 1;
        self.inv_width = 1.0 / w;
        self.cur_vb = self.vb_of(min_at);
        self.horizon_vb = self.cur_vb.saturating_add(nb as u64);
        for e in all {
            self.file(e);
        }
    }

    /// Schedule `kind` after a delay.
    pub fn after(&mut self, delay: SimTime, kind: EventKind) {
        self.schedule(self.now + delay, kind);
    }

    /// Time of the earliest pending event, positioning the wheel scan so
    /// the following [`Engine::next`] pops it in O(1). `&mut` because the
    /// scan position (and, on a drained rotation, the wheel geometry)
    /// advances; the observable queue state is unchanged.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        loop {
            if self.wheel_len == 0 {
                if self.overflow.is_empty() {
                    return None;
                }
                self.rebuild(); // rotation drained: pull the overflow in
                continue;
            }
            // all wheel entries are earlier than everything in overflow
            // (filing splits strictly at horizon_vb), so scanning forward
            // from cur_vb finds the global minimum
            let mut scanned = 0usize;
            loop {
                let q = &self.buckets[(self.cur_vb & self.mask) as usize];
                if let Some(front) = q.front() {
                    // the front is this bucket's minimum; it belongs to the
                    // current virtual bucket or a later rotation
                    if self.vb_of(front.at) == self.cur_vb {
                        return Some(front.at);
                    }
                }
                self.cur_vb += 1;
                scanned += 1;
                if scanned > self.buckets.len() {
                    // a full idle rotation: geometry is stale, recompute
                    self.rebuild();
                    break;
                }
            }
        }
    }

    /// Express-dispatch gate: would a hypothetical event at `at` be the
    /// very next dispatch, ahead of every pending event?
    ///
    /// Strict `<` against [`Engine::peek_time`], and deliberately so: an
    /// event scheduled at *exactly* `peek_time` receives a higher `seq`
    /// than the already-pending same-time events and therefore
    /// dispatches *after* them (the FIFO tie-break
    /// `fifo_tie_at_peek_time` pins for both this engine and
    /// [`reference::HeapEngine`]). Only a strictly earlier time
    /// guarantees nothing can interleave before it, which is what lets
    /// the streamed core commit such an event inline (hop fusion)
    /// instead of filing it.
    #[inline]
    pub fn would_dispatch_next(&mut self, at: SimTime) -> bool {
        match self.peek_time() {
            Some(t) => at < t,
            None => true,
        }
    }

    /// Pop the next event, advancing the clock. None when drained.
    /// (Deliberately not an `Iterator`: callers interleave `schedule`.)
    #[allow(clippy::should_implement_trait)]
    #[inline]
    pub fn next(&mut self) -> Option<(SimTime, EventKind)> {
        let at = self.peek_time()?;
        let q = &mut self.buckets[(self.cur_vb & self.mask) as usize];
        let e = q.pop_front().expect("peek_time positioned a non-empty bucket");
        debug_assert!(e.at == at);
        debug_assert!(e.at >= self.now);
        self.wheel_len -= 1;
        self.now = e.at;
        self.dispatched += 1;
        let i = e.slot as usize;
        let kind = decode(self.tags[i], self.w0[i], self.w1[i]);
        self.free.push(e.slot);
        Some((e.at, kind))
    }

    pub fn is_empty(&self) -> bool {
        self.wheel_len == 0 && self.overflow.is_empty()
    }

    pub fn pending(&self) -> usize {
        self.wheel_len + self.overflow.len()
    }

    /// Slab high-water mark: the max number of simultaneously pending
    /// events seen so far (capacity telemetry for the §Perf design).
    pub fn slab_slots(&self) -> usize {
        self.tags.len()
    }

    /// Capture the complete queue state (see [`EngineSnapshot`]). A
    /// field-wise clone: O(pending events + slab slots), no rebuild.
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            buckets: self.buckets.clone(),
            mask: self.mask,
            inv_width: self.inv_width,
            min_width: self.min_width,
            cur_vb: self.cur_vb,
            horizon_vb: self.horizon_vb,
            wheel_len: self.wheel_len,
            overflow: self.overflow.clone(),
            tags: self.tags.clone(),
            w0: self.w0.clone(),
            w1: self.w1.clone(),
            free: self.free.clone(),
            now: self.now,
            seq: self.seq,
            dispatched: self.dispatched,
        }
    }

    /// Roll the engine back to a state captured by [`Engine::snapshot`].
    /// Every observable (dispatch order, `now`, `dispatched`,
    /// `slab_slots`) is exactly as of the snapshot; `clone_from` reuses
    /// the live allocations, so a rollback allocates only where the
    /// snapshot outgrew the current buffers.
    pub fn restore(&mut self, snap: &EngineSnapshot) {
        self.buckets.clone_from(&snap.buckets);
        self.mask = snap.mask;
        self.inv_width = snap.inv_width;
        self.min_width = snap.min_width;
        self.cur_vb = snap.cur_vb;
        self.horizon_vb = snap.horizon_vb;
        self.wheel_len = snap.wheel_len;
        self.overflow.clone_from(&snap.overflow);
        self.tags.clone_from(&snap.tags);
        self.w0.clone_from(&snap.w0);
        self.w1.clone_from(&snap.w1);
        self.free.clone_from(&snap.free);
        self.now = snap.now;
        self.seq = snap.seq;
        self.dispatched = snap.dispatched;
    }
}

/// The pre-calendar binary-heap engine, kept verbatim as the parity
/// oracle for the calendar queue (the PR-1 `SerialRouter` pattern): the
/// property test `calendar_queue_matches_heap_reference` pins dispatch
/// order — including `seq` tie-breaks — byte-identical between the two.
pub mod reference {
    use super::{EventKind, SimTime};
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    /// Heap key: ordering state only; the payload lives in the slab.
    #[derive(Clone, Copy, Debug)]
    struct HeapKey {
        at: SimTime,
        seq: u64,
        slot: u32,
    }

    impl PartialEq for HeapKey {
        fn eq(&self, other: &Self) -> bool {
            self.cmp(other) == Ordering::Equal
        }
    }
    impl Eq for HeapKey {}
    impl PartialOrd for HeapKey {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for HeapKey {
        fn cmp(&self, other: &Self) -> Ordering {
            // BinaryHeap is a max-heap: invert for earliest-first
            other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
        }
    }

    /// The binary-heap event queue + clock (reference implementation).
    #[derive(Debug, Default)]
    pub struct HeapEngine {
        heap: BinaryHeap<HeapKey>,
        slab: Vec<EventKind>,
        free: Vec<u32>,
        now: SimTime,
        seq: u64,
        dispatched: u64,
    }

    impl HeapEngine {
        pub fn new() -> HeapEngine {
            HeapEngine::default()
        }

        pub fn now(&self) -> SimTime {
            self.now
        }

        pub fn dispatched(&self) -> u64 {
            self.dispatched
        }

        /// Schedule `kind` at absolute time `at` (>= now); same panics as
        /// [`super::Engine::schedule`].
        pub fn schedule(&mut self, at: SimTime, kind: EventKind) {
            assert!(at.is_finite(), "non-finite event time {at}");
            assert!(at >= self.now, "schedule into the past: {at} < {}", self.now);
            self.seq += 1;
            let slot = match self.free.pop() {
                Some(s) => {
                    self.slab[s as usize] = kind;
                    s
                }
                None => {
                    self.slab.push(kind);
                    (self.slab.len() - 1) as u32
                }
            };
            self.heap.push(HeapKey { at, seq: self.seq, slot });
        }

        pub fn after(&mut self, delay: SimTime, kind: EventKind) {
            self.schedule(self.now + delay, kind);
        }

        /// Time of the earliest pending event (`&mut` only for signature
        /// parity with the calendar engine).
        pub fn peek_time(&mut self) -> Option<SimTime> {
            self.heap.peek().map(|k| k.at)
        }

        /// Express-dispatch gate; same strict-`<` tie semantics as
        /// [`super::Engine::would_dispatch_next`] (an event filed at
        /// exactly `peek_time` loses the `seq` tie-break to everything
        /// already pending there).
        #[inline]
        pub fn would_dispatch_next(&mut self, at: SimTime) -> bool {
            match self.peek_time() {
                Some(t) => at < t,
                None => true,
            }
        }

        #[allow(clippy::should_implement_trait)]
        pub fn next(&mut self) -> Option<(SimTime, EventKind)> {
            let k = self.heap.pop()?;
            debug_assert!(k.at >= self.now);
            self.now = k.at;
            self.dispatched += 1;
            let kind = self.slab[k.slot as usize];
            self.free.push(k.slot);
            Some((k.at, kind))
        }

        pub fn is_empty(&self) -> bool {
            self.heap.is_empty()
        }

        pub fn pending(&self) -> usize {
            self.heap.len()
        }

        pub fn slab_slots(&self) -> usize {
            self.slab.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut e = Engine::new();
        e.schedule(30.0, EventKind::Custom { tag: 3 });
        e.schedule(10.0, EventKind::Custom { tag: 1 });
        e.schedule(20.0, EventKind::Custom { tag: 2 });
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = e.next() {
            tags.push(tag);
        }
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(e.now(), 30.0);
        assert_eq!(e.dispatched(), 3);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut e = Engine::new();
        for tag in 0..100 {
            e.schedule(5.0, EventKind::Custom { tag });
        }
        let mut last = None;
        while let Some((_, EventKind::Custom { tag })) = e.next() {
            if let Some(l) = last {
                assert!(tag > l, "FIFO violated: {tag} after {l}");
            }
            last = Some(tag);
        }
        assert_eq!(last, Some(99));
    }

    #[test]
    fn after_is_relative_to_now() {
        let mut e = Engine::new();
        e.schedule(100.0, EventKind::Custom { tag: 0 });
        e.next();
        e.after(50.0, EventKind::Custom { tag: 1 });
        let (at, _) = e.next().unwrap();
        assert_eq!(at, 150.0);
    }

    #[test]
    fn clock_monotone() {
        let mut e = Engine::new();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..1000 {
            e.schedule(rng.f64() * 1e6, EventKind::Custom { tag: 0 });
        }
        let mut last = 0.0;
        let mut n = 0;
        while let Some((at, _)) = e.next() {
            assert!(at >= last);
            last = at;
            n += 1;
        }
        assert_eq!(n, 1000);
    }

    #[test]
    fn peek_matches_next() {
        let mut e = Engine::new();
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..500 {
            e.schedule(rng.f64() * 1e4, EventKind::Custom { tag: 0 });
        }
        while let Some(t) = e.peek_time() {
            let (at, _) = e.next().unwrap();
            assert_eq!(at, t, "peek_time disagreed with next");
        }
        assert!(e.is_empty());
    }

    /// The fact that forces the hop-fusion gate to be strict `<`: an
    /// event scheduled at exactly `peek_time` dispatches AFTER the
    /// already-pending same-time events (FIFO `seq` tie-break), in both
    /// the calendar engine and the heap reference.
    #[test]
    fn fifo_tie_at_peek_time() {
        let mut e = Engine::new();
        e.schedule(10.0, EventKind::Custom { tag: 0 });
        e.schedule(10.0, EventKind::Custom { tag: 1 });
        assert_eq!(e.peek_time(), Some(10.0));
        // an event filed at exactly peek_time must lose the tie-break...
        e.schedule(10.0, EventKind::Custom { tag: 2 });
        let order: Vec<i64> = std::iter::from_fn(|| e.next())
            .map(|(_, k)| match k {
                EventKind::Custom { tag } => tag as i64,
                _ => -1,
            })
            .collect();
        assert_eq!(order, vec![0, 1, 2], "late same-time event jumped the queue");

        let mut h = reference::HeapEngine::new();
        h.schedule(10.0, EventKind::Custom { tag: 0 });
        h.schedule(10.0, EventKind::Custom { tag: 1 });
        assert_eq!(h.peek_time(), Some(10.0));
        h.schedule(10.0, EventKind::Custom { tag: 2 });
        let order: Vec<i64> = std::iter::from_fn(|| h.next())
            .map(|(_, k)| match k {
                EventKind::Custom { tag } => tag as i64,
                _ => -1,
            })
            .collect();
        assert_eq!(order, vec![0, 1, 2], "heap reference disagreed on the tie-break");
    }

    /// ...which is exactly what `would_dispatch_next` encodes: true
    /// strictly below `peek_time`, false at it, true on an empty queue.
    #[test]
    fn would_dispatch_next_is_strict() {
        let mut e = Engine::new();
        assert!(e.would_dispatch_next(5.0), "empty queue: anything dispatches next");
        e.schedule(10.0, EventKind::Custom { tag: 0 });
        assert!(e.would_dispatch_next(9.999));
        assert!(!e.would_dispatch_next(10.0), "a tie files behind the pending event");
        assert!(!e.would_dispatch_next(10.001));

        let mut h = reference::HeapEngine::new();
        assert!(h.would_dispatch_next(5.0));
        h.schedule(10.0, EventKind::Custom { tag: 0 });
        assert!(h.would_dispatch_next(9.999));
        assert!(!h.would_dispatch_next(10.0));
        assert!(!h.would_dispatch_next(10.001));
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_timestamp_rejected() {
        let mut e = Engine::new();
        e.schedule(f64::NAN, EventKind::Custom { tag: 0 });
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_timestamp_rejected() {
        let mut e = Engine::new();
        e.schedule(f64::INFINITY, EventKind::Custom { tag: 0 });
    }

    #[test]
    #[should_panic(expected = "schedule into the past")]
    fn past_scheduling_rejected_in_release_too() {
        let mut e = Engine::new();
        e.schedule(100.0, EventKind::Custom { tag: 0 });
        e.next();
        // a negative delay must not silently corrupt causality
        e.after(-50.0, EventKind::Custom { tag: 1 });
    }

    #[test]
    #[should_panic(expected = "schedule into the past")]
    fn reference_heap_also_rejects_past_scheduling() {
        let mut e = reference::HeapEngine::new();
        e.schedule(100.0, EventKind::Custom { tag: 0 });
        e.next();
        e.after(-50.0, EventKind::Custom { tag: 1 });
    }

    #[test]
    fn slab_slots_bounded_by_peak_concurrency() {
        let mut e = Engine::new();
        // repeated schedule/drain cycles: never more than 8 pending at
        // once, so the slab must not grow past 8 slots
        for round in 0..100u64 {
            for i in 0..8 {
                e.schedule(round as f64 * 10.0 + i as f64, EventKind::Custom { tag: i });
            }
            for _ in 0..8 {
                e.next().unwrap();
            }
        }
        assert!(e.slab_slots() <= 8, "slab leaked: {} slots", e.slab_slots());
        assert_eq!(e.dispatched(), 800);
    }

    #[test]
    fn payloads_survive_slot_recycling() {
        let mut e = Engine::new();
        e.schedule(1.0, EventKind::Arrive { id: 7, hop: 3 });
        assert_eq!(e.next(), Some((1.0, EventKind::Arrive { id: 7, hop: 3 })));
        // the freed slot is reused; the new payload must win
        e.schedule(2.0, EventKind::Complete { id: 9 });
        assert_eq!(e.slab_slots(), 1);
        assert_eq!(e.next(), Some((2.0, EventKind::Complete { id: 9 })));
    }

    #[test]
    fn soa_payloads_round_trip_every_kind() {
        // the SoA encode/decode boundary must be lossless for each
        // variant, including extreme field values
        let kinds = [
            EventKind::Arrive { id: (u32::MAX as usize) << 8, hop: 511 },
            EventKind::Complete { id: 0 },
            EventKind::Depart { link: u32::MAX, dir: 1 },
            EventKind::Custom { tag: u64::MAX },
        ];
        let mut e = Engine::new();
        for (i, k) in kinds.iter().enumerate() {
            e.schedule(i as f64, *k);
        }
        for k in kinds {
            assert_eq!(e.next().map(|(_, ev)| ev), Some(k));
        }
        assert!(e.is_empty());
    }

    #[test]
    fn far_future_overflow_round_trips() {
        // events far beyond the initial wheel horizon must park in the
        // overflow list and come back in order once the wheel drains
        let mut e = Engine::new();
        e.schedule(1e9, EventKind::Custom { tag: 2 });
        e.schedule(0.5, EventKind::Custom { tag: 0 });
        e.schedule(2e9, EventKind::Custom { tag: 3 });
        e.schedule(1.5, EventKind::Custom { tag: 1 });
        assert_eq!(e.pending(), 4);
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = e.next() {
            tags.push(tag);
        }
        assert_eq!(tags, vec![0, 1, 2, 3]);
        assert_eq!(e.now(), 2e9);
    }

    #[test]
    fn interleaved_schedule_and_dispatch_stay_ordered() {
        // rolling window, like a live simulation: each dispatch schedules
        // a new event one window ahead
        let mut e = Engine::with_granularity(0.25);
        for i in 0..256u64 {
            e.schedule(i as f64, EventKind::Custom { tag: i });
        }
        let mut fired = 0u64;
        let mut last = 0.0;
        while fired < 20_000 {
            let (now, _) = e.next().unwrap();
            assert!(now >= last);
            last = now;
            e.schedule(now + 256.0, EventKind::Custom { tag: 0 });
            fired += 1;
        }
        assert_eq!(e.pending(), 256);
    }

    #[test]
    fn snapshot_restore_replays_byte_identically() {
        // run half a random schedule, snapshot, drain the rest twice —
        // the restored replay must reproduce the first drain exactly,
        // including interleaved re-schedules and the dispatch counter
        let mut rng = crate::util::Rng::new(0x57A7E);
        let mut e = Engine::with_granularity(0.5);
        for i in 0..800u64 {
            e.schedule(rng.f64() * 1e5, EventKind::Custom { tag: i });
        }
        for _ in 0..400 {
            e.next().unwrap();
        }
        let snap = e.snapshot();
        let drain = |e: &mut Engine| {
            let mut out = Vec::new();
            while let Some((at, ev)) = e.next() {
                out.push((at, ev));
                if out.len() % 7 == 0 {
                    e.after(3.25, EventKind::Custom { tag: out.len() as u64 });
                }
            }
            (out, e.now(), e.dispatched(), e.slab_slots())
        };
        let a = drain(&mut e);
        e.restore(&snap);
        let b = drain(&mut e);
        assert_eq!(a, b);
    }

    #[test]
    fn restore_rewinds_clock_and_dispatch_count() {
        let mut e = Engine::new();
        for t in [10.0, 20.0, 30.0] {
            e.schedule(t, EventKind::Custom { tag: t as u64 });
        }
        e.next();
        let snap = e.snapshot();
        e.next();
        e.next();
        assert_eq!((e.now(), e.dispatched(), e.pending()), (30.0, 3, 0));
        e.restore(&snap);
        assert_eq!((e.now(), e.dispatched(), e.pending()), (10.0, 1, 2));
        assert_eq!(e.next(), Some((20.0, EventKind::Custom { tag: 20 })));
    }

    #[test]
    fn matches_reference_heap_on_random_interleavings() {
        // inline smoke version of the full property test in
        // tests/prop_invariants.rs
        let mut rng = crate::util::Rng::new(0xCA1);
        let mut cal = Engine::new();
        let mut heap = reference::HeapEngine::new();
        let mut out_cal = Vec::new();
        let mut out_heap = Vec::new();
        for step in 0..5_000u64 {
            if rng.f64() < 0.6 {
                // mix of near, same-timestamp, and far-future schedules
                let base = cal.now();
                let at = match rng.below(4) {
                    0 => base,
                    1 => base + rng.f64() * 10.0,
                    2 => base + rng.f64() * 1_000.0,
                    _ => base + 1e7 + rng.f64() * 1e9,
                };
                cal.schedule(at, EventKind::Custom { tag: step });
                heap.schedule(at, EventKind::Custom { tag: step });
            } else {
                out_cal.push(cal.next());
                out_heap.push(heap.next());
            }
        }
        while let Some(ev) = cal.next() {
            out_cal.push(Some(ev));
        }
        while let Some(ev) = heap.next() {
            out_heap.push(Some(ev));
        }
        assert_eq!(out_cal, out_heap);
        assert_eq!(cal.dispatched(), heap.dispatched());
    }
}
