//! Core event loop: a min-heap of timestamped events dispatched in order.
//!
//! # Performance architecture (§Perf)
//!
//! The heap holds lean `(time, seq, u32 handle)` keys; event payloads sit
//! in a slot slab indexed by the handle and recycled through a free list.
//! Heap sift operations therefore move 24-byte keys instead of full
//! payload-carrying events, and the slab's high-water mark equals the
//! maximum number of *concurrently pending* events, not the total
//! scheduled — a million-transaction run recycles a few thousand slots.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in nanoseconds.
pub type SimTime = f64;

/// What an event does when it fires (interpreted by the driver).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Transaction `id` arrives at hop `hop` of its path.
    Arrive { id: usize, hop: usize },
    /// Transaction `id` completes end-to-end.
    Complete { id: usize },
    /// Driver-defined.
    Custom { tag: u64 },
}

/// Heap key: ordering state only; the payload lives in the slab.
#[derive(Clone, Copy, Debug)]
struct HeapKey {
    at: SimTime,
    seq: u64, // tie-break: FIFO among simultaneous events
    slot: u32,
}

impl PartialEq for HeapKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapKey {}
impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first. `at` is
        // guaranteed finite by `schedule`, so total_cmp agrees with the
        // numeric order.
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue + clock.
#[derive(Debug, Default)]
pub struct Engine {
    heap: BinaryHeap<HeapKey>,
    slab: Vec<EventKind>,
    free: Vec<u32>,
    now: SimTime,
    seq: u64,
    dispatched: u64,
}

impl Engine {
    pub fn new() -> Engine {
        Engine::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events dispatched so far.
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Schedule `kind` at absolute time `at` (>= now). Panics on NaN or
    /// infinite timestamps: a non-finite key would silently corrupt the
    /// heap order (float comparison has no total order across NaN).
    pub fn schedule(&mut self, at: SimTime, kind: EventKind) {
        assert!(at.is_finite(), "non-finite event time {at}");
        debug_assert!(at >= self.now, "schedule into the past: {at} < {}", self.now);
        self.seq += 1;
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = kind;
                s
            }
            None => {
                self.slab.push(kind);
                (self.slab.len() - 1) as u32
            }
        };
        self.heap.push(HeapKey { at, seq: self.seq, slot });
    }

    /// Schedule `kind` after a delay.
    pub fn after(&mut self, delay: SimTime, kind: EventKind) {
        self.schedule(self.now + delay, kind);
    }

    /// Pop the next event, advancing the clock. None when drained.
    /// (Deliberately not an `Iterator`: callers interleave `schedule`.)
    #[allow(clippy::should_implement_trait)]
    #[inline]
    pub fn next(&mut self) -> Option<(SimTime, EventKind)> {
        let k = self.heap.pop()?;
        debug_assert!(k.at >= self.now);
        self.now = k.at;
        self.dispatched += 1;
        let kind = self.slab[k.slot as usize];
        self.free.push(k.slot);
        Some((k.at, kind))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Slab high-water mark: the max number of simultaneously pending
    /// events seen so far (capacity telemetry for the §Perf design).
    pub fn slab_slots(&self) -> usize {
        self.slab.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut e = Engine::new();
        e.schedule(30.0, EventKind::Custom { tag: 3 });
        e.schedule(10.0, EventKind::Custom { tag: 1 });
        e.schedule(20.0, EventKind::Custom { tag: 2 });
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = e.next() {
            tags.push(tag);
        }
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(e.now(), 30.0);
        assert_eq!(e.dispatched(), 3);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut e = Engine::new();
        for tag in 0..100 {
            e.schedule(5.0, EventKind::Custom { tag });
        }
        let mut last = None;
        while let Some((_, EventKind::Custom { tag })) = e.next() {
            if let Some(l) = last {
                assert!(tag > l, "FIFO violated: {tag} after {l}");
            }
            last = Some(tag);
        }
    }

    #[test]
    fn after_is_relative_to_now() {
        let mut e = Engine::new();
        e.schedule(100.0, EventKind::Custom { tag: 0 });
        e.next();
        e.after(50.0, EventKind::Custom { tag: 1 });
        let (at, _) = e.next().unwrap();
        assert_eq!(at, 150.0);
    }

    #[test]
    fn clock_monotone() {
        let mut e = Engine::new();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..1000 {
            e.schedule(rng.f64() * 1e6, EventKind::Custom { tag: 0 });
        }
        let mut last = 0.0;
        while let Some((at, _)) = e.next() {
            assert!(at >= last);
            last = at;
        }
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_timestamp_rejected() {
        let mut e = Engine::new();
        e.schedule(f64::NAN, EventKind::Custom { tag: 0 });
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_timestamp_rejected() {
        let mut e = Engine::new();
        e.schedule(f64::INFINITY, EventKind::Custom { tag: 0 });
    }

    #[test]
    fn slab_slots_bounded_by_peak_concurrency() {
        let mut e = Engine::new();
        // repeated schedule/drain cycles: never more than 8 pending at
        // once, so the slab must not grow past 8 slots
        for round in 0..100u64 {
            for i in 0..8 {
                e.schedule(round as f64 * 10.0 + i as f64, EventKind::Custom { tag: i });
            }
            for _ in 0..8 {
                e.next().unwrap();
            }
        }
        assert!(e.slab_slots() <= 8, "slab leaked: {} slots", e.slab_slots());
        assert_eq!(e.dispatched(), 800);
    }

    #[test]
    fn payloads_survive_slot_recycling() {
        let mut e = Engine::new();
        e.schedule(1.0, EventKind::Arrive { id: 7, hop: 3 });
        assert_eq!(e.next(), Some((1.0, EventKind::Arrive { id: 7, hop: 3 })));
        // the freed slot is reused; the new payload must win
        e.schedule(2.0, EventKind::Complete { id: 9 });
        assert_eq!(e.slab_slots(), 1);
        assert_eq!(e.next(), Some((2.0, EventKind::Complete { id: 9 })));
    }
}
