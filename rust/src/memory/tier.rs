//! The two-tier memory hierarchy of §5.

use super::device::MemDevice;

/// Which tier a page/allocation lives in.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tier {
    /// Tier-1: accelerator-local HBM, unified intra-cluster by XLink and
    /// (in ScalePool) made coherent by coherence-centric CXL.
    Tier1Local,
    /// Tier-1 remote: another accelerator's HBM in the same or another
    /// cluster, reached over XLink (non-coherent) or CXL.cache (coherent).
    Tier1Remote,
    /// Tier-2: capacity-oriented CXL memory nodes (no CPUs/accelerators).
    Tier2Pool,
    /// Overflow beyond the pool: external storage / distributed FS.
    Storage,
}

/// Capacity specification of a tier within one ScalePool deployment.
#[derive(Clone, Copy, Debug)]
pub struct TierSpec {
    pub tier: Tier,
    pub device: MemDevice,
    /// Total capacity of this tier visible to one accelerator's workload,
    /// bytes.
    pub capacity: f64,
}

impl TierSpec {
    pub fn tier1_local(capacity: f64) -> TierSpec {
        TierSpec { tier: Tier::Tier1Local, device: MemDevice::Hbm3e, capacity }
    }
    pub fn tier1_remote(capacity: f64) -> TierSpec {
        TierSpec { tier: Tier::Tier1Remote, device: MemDevice::Hbm3e, capacity }
    }
    pub fn tier2(capacity: f64) -> TierSpec {
        TierSpec { tier: Tier::Tier2Pool, device: MemDevice::CxlDram, capacity }
    }
    pub fn storage(capacity: f64) -> TierSpec {
        TierSpec { tier: Tier::Storage, device: MemDevice::NvmeSsd, capacity }
    }
}

/// Split a working set across an ordered tier list (waterfall placement:
/// hottest data to the fastest tier). Returns (spec, bytes-resident) pairs.
pub fn waterfall_placement(working_set: f64, tiers: &[TierSpec]) -> Vec<(TierSpec, f64)> {
    let mut rest = working_set;
    let mut out = Vec::with_capacity(tiers.len());
    for &t in tiers {
        let here = rest.min(t.capacity);
        out.push((t, here));
        rest -= here;
        if rest <= 0.0 {
            break;
        }
    }
    if rest > 0.0 {
        // anything left spills to (implicit, unbounded) storage
        out.push((TierSpec::storage(f64::INFINITY), rest));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::GB;

    #[test]
    fn fits_in_first_tier() {
        let tiers = [TierSpec::tier1_local(192.0 * GB), TierSpec::tier2(1e4 * GB)];
        let p = waterfall_placement(100.0 * GB, &tiers);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].1, 100.0 * GB);
    }

    #[test]
    fn overflows_in_order() {
        let tiers = [TierSpec::tier1_local(192.0 * GB), TierSpec::tier1_remote(800.0 * GB), TierSpec::tier2(1e4 * GB)];
        let p = waterfall_placement(1_500.0 * GB, &tiers);
        assert_eq!(p.len(), 3);
        assert_eq!(p[0].1, 192.0 * GB);
        assert_eq!(p[1].1, 800.0 * GB);
        assert!((p[2].1 - 508.0 * GB).abs() < 1.0);
    }

    #[test]
    fn spills_to_storage_when_all_full() {
        let tiers = [TierSpec::tier1_local(10.0 * GB)];
        let p = waterfall_placement(25.0 * GB, &tiers);
        assert_eq!(p.len(), 2);
        assert_eq!(p[1].0.tier, Tier::Storage);
        assert_eq!(p[1].1, 15.0 * GB);
    }

    #[test]
    fn conservation_of_bytes() {
        let tiers = [TierSpec::tier1_local(7.0), TierSpec::tier1_remote(11.0), TierSpec::tier2(13.0)];
        for ws in [0.5, 7.0, 10.0, 31.0, 100.0] {
            let placed: f64 = waterfall_placement(ws, &tiers).iter().map(|(_, b)| b).sum();
            assert!((placed - ws).abs() < 1e-9, "ws {ws} placed {placed}");
        }
    }
}
