//! Composable memory-pool allocator: the mechanism behind "dynamic
//! aggregation of distributed memory resources into composable memory
//! pools" (§4). Regions live on fabric nodes (accelerator HBM carve-outs
//! or tier-2 memory nodes); allocations may interleave across regions.

use crate::fabric::NodeId;
use crate::memory::tier::Tier;

/// A contributing region of a pool.
#[derive(Clone, Debug)]
pub struct Region {
    pub node: NodeId,
    pub tier: Tier,
    pub capacity: f64,
    pub used: f64,
}

/// An allocation handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AllocId(pub u64);

/// One allocation: bytes per region.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub id: AllocId,
    pub total: f64,
    /// (region index, bytes) placements.
    pub extents: Vec<(usize, f64)>,
}

#[derive(Debug, PartialEq)]
pub enum PoolError {
    OutOfMemory { requested: f64, available: f64 },
    UnknownAlloc,
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::OutOfMemory { requested, available } => {
                write!(f, "out of memory: requested {requested} bytes, {available} available")
            }
            PoolError::UnknownAlloc => write!(f, "unknown allocation"),
        }
    }
}

impl std::error::Error for PoolError {}

/// Placement policy for new allocations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Fill regions in order (locality-first: caller orders regions from
    /// nearest to farthest).
    FirstFit,
    /// Split evenly across all regions with space (bandwidth-interleaved).
    Interleave,
    /// Prefer the region with most free space (load balance).
    WorstFit,
}

/// A composable pool over multiple regions.
#[derive(Clone, Debug, Default)]
pub struct MemoryPool {
    regions: Vec<Region>,
    allocs: Vec<Option<Allocation>>,
    next_id: u64,
}

impl MemoryPool {
    pub fn new() -> Self {
        MemoryPool::default()
    }

    pub fn add_region(&mut self, node: NodeId, tier: Tier, capacity: f64) -> usize {
        self.regions.push(Region { node, tier, capacity, used: 0.0 });
        self.regions.len() - 1
    }

    pub fn regions(&self) -> &[Region] {
        &self.regions
    }

    pub fn capacity(&self) -> f64 {
        self.regions.iter().map(|r| r.capacity).sum()
    }

    pub fn used(&self) -> f64 {
        self.regions.iter().map(|r| r.used).sum()
    }

    pub fn available(&self) -> f64 {
        self.capacity() - self.used()
    }

    /// Allocate `bytes` with the given policy.
    pub fn alloc(&mut self, bytes: f64, policy: Placement) -> Result<Allocation, PoolError> {
        assert!(bytes > 0.0);
        if bytes > self.available() + 1e-9 {
            return Err(PoolError::OutOfMemory { requested: bytes, available: self.available() });
        }
        let mut extents = Vec::new();
        match policy {
            Placement::FirstFit => {
                let mut rest = bytes;
                for (i, r) in self.regions.iter_mut().enumerate() {
                    let free = r.capacity - r.used;
                    if free <= 0.0 {
                        continue;
                    }
                    let take = rest.min(free);
                    r.used += take;
                    extents.push((i, take));
                    rest -= take;
                    if rest <= 1e-9 {
                        break;
                    }
                }
            }
            Placement::Interleave => {
                // proportional split over free space, single pass
                let frees: Vec<f64> = self.regions.iter().map(|r| r.capacity - r.used).collect();
                let total_free: f64 = frees.iter().sum();
                let mut assigned = 0.0;
                let n = self.regions.len();
                for (i, r) in self.regions.iter_mut().enumerate() {
                    let share = if i + 1 == n {
                        bytes - assigned // absorb rounding
                    } else {
                        bytes * frees[i] / total_free
                    };
                    let take = share.min(r.capacity - r.used);
                    if take > 0.0 {
                        r.used += take;
                        extents.push((i, take));
                        assigned += take;
                    }
                }
            }
            Placement::WorstFit => {
                let mut rest = bytes;
                while rest > 1e-9 {
                    let (i, free) = self
                        .regions
                        .iter()
                        .enumerate()
                        .map(|(i, r)| (i, r.capacity - r.used))
                        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                        .unwrap();
                    if free <= 1e-9 {
                        break;
                    }
                    let take = rest.min(free);
                    self.regions[i].used += take;
                    // merge with an existing extent on the same region
                    if let Some(e) = extents.iter_mut().find(|(ri, _): &&mut (usize, f64)| *ri == i) {
                        e.1 += take;
                    } else {
                        extents.push((i, take));
                    }
                    rest -= take;
                }
            }
        }
        let placed: f64 = extents.iter().map(|(_, b)| b).sum();
        debug_assert!((placed - bytes).abs() < 1e-6, "placed {placed} != {bytes}");
        let id = AllocId(self.next_id);
        self.next_id += 1;
        let alloc = Allocation { id, total: bytes, extents };
        self.allocs.push(Some(alloc.clone()));
        Ok(alloc)
    }

    /// Look up a live allocation by handle.
    pub fn get(&self, id: AllocId) -> Option<&Allocation> {
        self.allocs.get(id.0 as usize)?.as_ref()
    }

    /// Free an allocation.
    pub fn free(&mut self, id: AllocId) -> Result<(), PoolError> {
        let slot = self
            .allocs
            .get_mut(id.0 as usize)
            .ok_or(PoolError::UnknownAlloc)?
            .take()
            .ok_or(PoolError::UnknownAlloc)?;
        for (i, b) in slot.extents {
            self.regions[i].used -= b;
            debug_assert!(self.regions[i].used >= -1e-6);
        }
        Ok(())
    }

    /// Invariant check: per-region usage equals the sum of live extents and
    /// never exceeds capacity.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut per_region = vec![0.0; self.regions.len()];
        for a in self.allocs.iter().flatten() {
            for &(i, b) in &a.extents {
                per_region[i] += b;
            }
        }
        for (i, r) in self.regions.iter().enumerate() {
            let tol = 1e-6f64.max(1e-12 * r.used.abs());
            if (r.used - per_region[i]).abs() > tol {
                return Err(format!("region {i}: used {} != live extents {}", r.used, per_region[i]));
            }
            if r.used > r.capacity + tol {
                return Err(format!("region {i}: used {} > capacity {}", r.used, r.capacity));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool3() -> MemoryPool {
        let mut p = MemoryPool::new();
        p.add_region(0, Tier::Tier1Local, 100.0);
        p.add_region(1, Tier::Tier1Remote, 200.0);
        p.add_region(2, Tier::Tier2Pool, 400.0);
        p
    }

    #[test]
    fn first_fit_prefers_early_regions() {
        let mut p = pool3();
        let a = p.alloc(80.0, Placement::FirstFit).unwrap();
        assert_eq!(a.extents, vec![(0, 80.0)]);
        let b = p.alloc(50.0, Placement::FirstFit).unwrap();
        assert_eq!(b.extents, vec![(0, 20.0), (1, 30.0)]);
        p.check_invariants().unwrap();
    }

    #[test]
    fn interleave_spreads() {
        let mut p = pool3();
        let a = p.alloc(70.0, Placement::Interleave).unwrap();
        assert_eq!(a.extents.len(), 3);
        // proportional to free space 100:200:400
        assert!((a.extents[0].1 - 10.0).abs() < 1e-6);
        assert!((a.extents[1].1 - 20.0).abs() < 1e-6);
        assert!((a.extents[2].1 - 40.0).abs() < 1e-6);
        p.check_invariants().unwrap();
    }

    #[test]
    fn worst_fit_targets_biggest_region() {
        let mut p = pool3();
        let a = p.alloc(100.0, Placement::WorstFit).unwrap();
        assert_eq!(a.extents, vec![(2, 100.0)]);
    }

    #[test]
    fn oom_detected() {
        let mut p = pool3();
        let e = p.alloc(701.0, Placement::FirstFit).unwrap_err();
        assert!(matches!(e, PoolError::OutOfMemory { .. }));
    }

    #[test]
    fn free_returns_space() {
        let mut p = pool3();
        let a = p.alloc(600.0, Placement::FirstFit).unwrap();
        assert!(p.alloc(200.0, Placement::FirstFit).is_err());
        p.free(a.id).unwrap();
        assert_eq!(p.used(), 0.0);
        assert!(p.alloc(200.0, Placement::FirstFit).is_ok());
        p.check_invariants().unwrap();
    }

    #[test]
    fn double_free_rejected() {
        let mut p = pool3();
        let a = p.alloc(10.0, Placement::FirstFit).unwrap();
        p.free(a.id).unwrap();
        assert_eq!(p.free(a.id), Err(PoolError::UnknownAlloc));
    }

    #[test]
    fn exact_fill() {
        let mut p = pool3();
        let a = p.alloc(700.0, Placement::FirstFit).unwrap();
        assert_eq!(a.total, 700.0);
        assert!(p.available() < 1e-9);
        p.check_invariants().unwrap();
    }
}
