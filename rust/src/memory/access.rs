//! Access-latency resolution: what it costs to touch one cache line,
//! depending on where the page lives and which mechanism reaches it.
//! This chain is what Figure 7 sweeps across working-set sizes.

use crate::coherence::software::SoftwareCopyModel;
use crate::memory::device::MemDevice;
use crate::memory::tier::{waterfall_placement, TierSpec};

/// Mechanism by which a (64 B) access is satisfied.
#[derive(Clone, Copy, Debug)]
pub enum AccessPath {
    /// Accelerator-local HBM.
    LocalHbm,
    /// Peer accelerator HBM over non-coherent XLink: software-managed page
    /// copy amortized over reuse, then local access to the copy.
    XlinkSwCopy(SoftwareCopyModel),
    /// Coherent CXL.cache access (tier-1 remote): request/data round trip
    /// over the fabric plus the remote HBM access; no software.
    CxlCoherent {
        /// Fabric round-trip (request out + data back), ns.
        fabric_rt_ns: f64,
        /// Extra coherence-protocol messages amortized per access, ns
        /// (directory lookups / occasional invalidations).
        coherence_ns: f64,
    },
    /// Tier-2 capacity pool over capacity-oriented CXL (CXL.mem/io).
    CxlTier2 { fabric_rt_ns: f64 },
    /// RDMA to a remote cluster (the scale-out baseline's overflow path).
    Rdma(SoftwareCopyModel),
    /// External storage / distributed FS.
    Storage,
}

impl AccessPath {
    /// Mean latency of one access via this path, ns.
    pub fn latency_ns(&self) -> f64 {
        match *self {
            AccessPath::LocalHbm => MemDevice::Hbm3e.access_ns(),
            AccessPath::XlinkSwCopy(m) => m.per_access_ns() + MemDevice::Hbm3e.access_ns(),
            AccessPath::CxlCoherent { fabric_rt_ns, coherence_ns } => {
                fabric_rt_ns + coherence_ns + MemDevice::Hbm3e.access_ns()
            }
            AccessPath::CxlTier2 { fabric_rt_ns } => fabric_rt_ns + MemDevice::CxlDram.access_ns(),
            AccessPath::Rdma(m) => m.per_access_ns() + MemDevice::Ddr5.access_ns(),
            AccessPath::Storage => MemDevice::NvmeSsd.access_ns(),
        }
    }
}

/// One of Figure 7's three system configurations: an ordered tier list and
/// the mechanism used for each tier.
#[derive(Clone, Debug)]
pub struct MemoryConfig {
    pub name: String,
    /// (capacity spec, access mechanism) from fastest to slowest.
    pub levels: Vec<(TierSpec, AccessPath)>,
}

impl MemoryConfig {
    /// Mean per-access latency for a working set accessed uniformly at
    /// random (the memory-intensive workloads of §2 — KV cache lookups,
    /// embedding gathers, RAG — have little locality, so residency share
    /// equals access share).
    pub fn mean_latency_ns(&self, working_set: f64) -> f64 {
        let specs: Vec<TierSpec> = self.levels.iter().map(|(s, _)| *s).collect();
        let placement = waterfall_placement(working_set, &specs);
        let mut acc = 0.0;
        // placement preserves level order; an extra trailing entry is the
        // implicit storage spill
        for (i, (_, bytes)) in placement.iter().enumerate() {
            let frac = bytes / working_set;
            let path = self.levels.get(i).map(|(_, p)| *p).unwrap_or(AccessPath::Storage);
            acc += frac * path.latency_ns();
        }
        acc
    }

    /// Latency with a hot-fraction model: `hot_frac` of accesses go to the
    /// fastest tier regardless of residency share (caching of hot pages in
    /// local HBM), the rest are uniform over the whole working set.
    pub fn mean_latency_with_locality(&self, working_set: f64, hot_frac: f64) -> f64 {
        let uniform = self.mean_latency_ns(working_set);
        let local = self.levels[0].1.latency_ns();
        hot_frac * local + (1.0 - hot_frac) * uniform
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::tier::Tier;
    use crate::util::units::GB;

    fn cfgs() -> (MemoryConfig, MemoryConfig) {
        let acc = 192.0 * GB;
        let cluster = 72.0 * acc;
        let baseline = MemoryConfig {
            name: "baseline".into(),
            levels: vec![
                (TierSpec::tier1_local(acc), AccessPath::LocalHbm),
                (
                    TierSpec::tier1_remote(cluster - acc),
                    AccessPath::XlinkSwCopy(SoftwareCopyModel::xlink_intra_rack()),
                ),
                (
                    TierSpec { tier: Tier::Tier2Pool, device: MemDevice::Ddr5, capacity: 10.0 * cluster },
                    AccessPath::Rdma(SoftwareCopyModel::rdma_inter_cluster()),
                ),
            ],
        };
        let scalepool = MemoryConfig {
            name: "scalepool".into(),
            levels: vec![
                (TierSpec::tier1_local(acc), AccessPath::LocalHbm),
                (
                    TierSpec::tier1_remote(cluster - acc),
                    AccessPath::CxlCoherent { fabric_rt_ns: 600.0, coherence_ns: 80.0 },
                ),
                (TierSpec::tier2(10.0 * cluster), AccessPath::CxlTier2 { fabric_rt_ns: 800.0 }),
            ],
        };
        (baseline, scalepool)
    }

    #[test]
    fn small_working_sets_identical() {
        let (b, s) = cfgs();
        let ws = 50.0 * GB;
        assert!((b.mean_latency_ns(ws) - s.mean_latency_ns(ws)).abs() < 1e-9);
    }

    #[test]
    fn latency_monotone_in_working_set() {
        let (b, _) = cfgs();
        let mut last = 0.0;
        for ws in [10.0, 100.0, 1_000.0, 20_000.0, 100_000.0] {
            let l = b.mean_latency_ns(ws * GB);
            assert!(l >= last, "ws {ws} GB: {l} < {last}");
            last = l;
        }
    }

    #[test]
    fn scalepool_wins_beyond_local_capacity() {
        let (b, s) = cfgs();
        let ws = 1_000.0 * GB; // beyond one accelerator, within cluster
        assert!(s.mean_latency_ns(ws) < b.mean_latency_ns(ws));
    }

    #[test]
    fn scalepool_wins_big_beyond_cluster() {
        let (b, s) = cfgs();
        let ws = 40_000.0 * GB; // beyond the 13.8 TB cluster
        let ratio = b.mean_latency_ns(ws) / s.mean_latency_ns(ws);
        assert!(ratio > 2.0, "expected large tier-2 win, got {ratio:.2}x");
    }

    #[test]
    fn hot_fraction_reduces_latency() {
        let (b, _) = cfgs();
        let ws = 40_000.0 * GB;
        assert!(b.mean_latency_with_locality(ws, 0.9) < b.mean_latency_ns(ws) * 0.3);
    }
}
