//! Memory device models: the raw media behind each tier.

/// A memory device technology.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemDevice {
    /// On-package HBM3e (accelerator-local, tier-1).
    Hbm3e,
    /// CPU-attached (LP)DDR5 — where the RDMA baseline offloads to.
    Ddr5,
    /// DRAM behind a CXL memory-node controller (tier-2 media).
    CxlDram,
    /// NVMe SSD — what tier-2 replaces for capacity overflow ("such
    /// scenarios traditionally rely on external storage ... with
    /// millisecond- to second-level latencies").
    NvmeSsd,
}

impl MemDevice {
    /// Device-side access latency (row access + controller), ns.
    pub fn access_ns(self) -> f64 {
        match self {
            MemDevice::Hbm3e => 100.0,
            MemDevice::Ddr5 => 90.0,
            MemDevice::CxlDram => 130.0, // DDR + CXL endpoint controller
            MemDevice::NvmeSsd => 20_000.0, // read latency (optimistic)
        }
    }

    /// Device bandwidth per stack/module, bytes/ns (GB/s).
    pub fn bandwidth(self) -> f64 {
        match self {
            MemDevice::Hbm3e => 1_000.0, // per-stack; B200 carries 8 stacks
            MemDevice::Ddr5 => 64.0,     // per channel pair
            MemDevice::CxlDram => 128.0, // bounded by the CXL x16 port
            MemDevice::NvmeSsd => 14.0,
        }
    }

    /// Typical capacity per unit (stack / DIMM set / module / drive), bytes.
    pub fn unit_capacity(self) -> f64 {
        match self {
            MemDevice::Hbm3e => 24.0 * 1e9 * 8.0 / 8.0, // 24 GB per stack
            MemDevice::Ddr5 => 128.0 * 1e9,
            MemDevice::CxlDram => 512.0 * 1e9, // dense memory-node module
            MemDevice::NvmeSsd => 4.0 * 1e12,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MemDevice::Hbm3e => "HBM3e",
            MemDevice::Ddr5 => "DDR5",
            MemDevice::CxlDram => "CXL-DRAM",
            MemDevice::NvmeSsd => "NVMe-SSD",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_ordering_matches_hierarchy() {
        assert!(MemDevice::Ddr5.access_ns() <= MemDevice::Hbm3e.access_ns() + 20.0);
        assert!(MemDevice::Hbm3e.access_ns() < MemDevice::CxlDram.access_ns());
        assert!(MemDevice::CxlDram.access_ns() * 100.0 < MemDevice::NvmeSsd.access_ns());
    }

    #[test]
    fn hbm_bandwidth_dominates() {
        assert!(MemDevice::Hbm3e.bandwidth() > 5.0 * MemDevice::CxlDram.bandwidth());
    }

    #[test]
    fn tier2_replaces_storage_not_dram() {
        // the paper's pitch: tier-2 turns ms-scale overflow into sub-µs
        let t2 = MemDevice::CxlDram.access_ns();
        let ssd = MemDevice::NvmeSsd.access_ns();
        assert!(ssd / t2 > 100.0);
    }
}
