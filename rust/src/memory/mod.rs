//! Memory subsystem: device models, the two-tier hierarchy of §5, the
//! composable pool allocator, and the access-latency resolution chain that
//! Figure 7 sweeps.

pub mod device;
pub mod tier;
pub mod pool;
pub mod access;

pub use access::{AccessPath, MemoryConfig};
pub use device::MemDevice;
pub use pool::{MemoryPool, PoolError, Region};
pub use tier::{Tier, TierSpec};
