//! Directory-based MESI coherence engine — the protocol semantics that
//! CXL.cache contributes to tier-1 (§4: "accelerators can directly access
//! remote memory at instruction-level granularity without software
//! involvement").
//!
//! One `Directory` tracks the global state of cache blocks across N agents
//! (accelerators). `read`/`write` drive the state machine and return the
//! *message count breakdown* of the transaction, from which the latency
//! model derives coherent-access cost (each message crosses the fabric).
//!
//! # Fabric-backed mode
//!
//! The `*_routed` variants additionally emit the individual protocol
//! messages *with endpoints* ([`ProtocolMsg`]): dir-request from the
//! requester to the block's home, interventions from the home to each
//! holder, data cache-to-cache or from the home, and acks. The
//! [`CoherenceTraffic`](super::CoherenceTraffic) source turns each message
//! into a routed fabric transaction, so coherent-access latency emerges
//! from link contention instead of `Messages::total() × hop_cost`.

use std::collections::HashMap;

/// Per-agent MESI state of a block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MesiState {
    Modified,
    Exclusive,
    Shared,
    Invalid,
}

/// Message counts incurred by one transaction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Messages {
    /// Requests to the home directory.
    pub dir_req: u32,
    /// Forwarded interventions / invalidations to other agents.
    pub interventions: u32,
    /// Data transfers (cache-to-cache or memory-to-cache).
    pub data: u32,
    /// Acks back to directory/requester.
    pub acks: u32,
}

impl Messages {
    pub fn total(&self) -> u32 {
        self.dir_req + self.interventions + self.data + self.acks
    }
}

/// Endpoint of a routed protocol message: a caching agent, or the block's
/// home (the directory plus backing memory — on ScalePool, CXL home-agent
/// logic at a memory node).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CohEndpoint {
    Agent(usize),
    Home,
}

/// Which protocol phase a routed message belongs to. Causal order within
/// one transaction: `DirReq` -> `Intervention`* -> `Data` -> `Ack`*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    DirReq,
    Intervention,
    Data,
    Ack,
}

/// One protocol message with endpoints, for fabric-backed simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProtocolMsg {
    pub kind: MsgKind,
    pub src: CohEndpoint,
    pub dst: CohEndpoint,
}

/// Cumulative statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct DirStats {
    pub reads: u64,
    pub writes: u64,
    pub hits: u64,
    pub cache_to_cache: u64,
    pub invalidations: u64,
    pub messages: u64,
}

/// Directory state for one block.
#[derive(Clone, Debug, Default)]
struct BlockEntry {
    /// agents holding the block in S (unordered — removal is O(1)
    /// swap-remove; nothing in the protocol depends on sharer order)
    sharers: Vec<usize>,
    /// agent holding M/E, if any
    owner: Option<usize>,
}

/// A full-map directory over `agents` caches.
#[derive(Clone, Debug)]
pub struct Directory {
    agents: usize,
    blocks: HashMap<u64, BlockEntry>,
    stats: DirStats,
}

impl Directory {
    pub fn new(agents: usize) -> Directory {
        assert!(agents >= 1);
        Directory { agents, blocks: HashMap::new(), stats: DirStats::default() }
    }

    pub fn stats(&self) -> DirStats {
        self.stats
    }

    /// State of `block` at `agent`.
    pub fn state_of(&self, agent: usize, block: u64) -> MesiState {
        match self.blocks.get(&block) {
            None => MesiState::Invalid,
            Some(e) => {
                if e.owner == Some(agent) {
                    // we do not distinguish M/E externally; M is the
                    // conservative answer for an owned block
                    MesiState::Modified
                } else if e.sharers.contains(&agent) {
                    MesiState::Shared
                } else {
                    MesiState::Invalid
                }
            }
        }
    }

    /// Agent `a` reads `block`. Returns the protocol messages incurred.
    pub fn read(&mut self, a: usize, block: u64) -> Messages {
        self.read_inner(a, block, None)
    }

    /// Like [`read`](Directory::read), additionally appending each
    /// message with endpoints to `out` (fabric-backed mode).
    pub fn read_routed(&mut self, a: usize, block: u64, out: &mut Vec<ProtocolMsg>) -> Messages {
        self.read_inner(a, block, Some(out))
    }

    fn read_inner(&mut self, a: usize, block: u64, mut sink: Option<&mut Vec<ProtocolMsg>>) -> Messages {
        assert!(a < self.agents);
        self.stats.reads += 1;
        let e = self.blocks.entry(block).or_default();
        let mut m = Messages::default();
        if e.owner == Some(a) || e.sharers.contains(&a) {
            // hit: no traffic
            self.stats.hits += 1;
            return m;
        }
        m.dir_req = 1;
        if let Some(out) = sink.as_mut() {
            out.push(ProtocolMsg { kind: MsgKind::DirReq, src: CohEndpoint::Agent(a), dst: CohEndpoint::Home });
        }
        match e.owner {
            Some(o) => {
                // owner forwards data, downgrades to S
                m.interventions = 1;
                m.data = 1;
                m.acks = 1;
                if let Some(out) = sink.as_mut() {
                    out.push(ProtocolMsg { kind: MsgKind::Intervention, src: CohEndpoint::Home, dst: CohEndpoint::Agent(o) });
                    out.push(ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Agent(o), dst: CohEndpoint::Agent(a) });
                    out.push(ProtocolMsg { kind: MsgKind::Ack, src: CohEndpoint::Agent(o), dst: CohEndpoint::Home });
                }
                e.sharers.push(o);
                e.sharers.push(a);
                e.owner = None;
                self.stats.cache_to_cache += 1;
            }
            None => {
                // from memory (home node)
                m.data = 1;
                if let Some(out) = sink.as_mut() {
                    out.push(ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Home, dst: CohEndpoint::Agent(a) });
                }
                if e.sharers.is_empty() {
                    // grant E
                    e.owner = Some(a);
                } else {
                    e.sharers.push(a);
                }
            }
        }
        self.stats.messages += m.total() as u64;
        m
    }

    /// Agent `a` writes `block`.
    pub fn write(&mut self, a: usize, block: u64) -> Messages {
        self.write_inner(a, block, None)
    }

    /// Like [`write`](Directory::write), additionally appending each
    /// message with endpoints to `out` (fabric-backed mode).
    pub fn write_routed(&mut self, a: usize, block: u64, out: &mut Vec<ProtocolMsg>) -> Messages {
        self.write_inner(a, block, Some(out))
    }

    fn write_inner(&mut self, a: usize, block: u64, mut sink: Option<&mut Vec<ProtocolMsg>>) -> Messages {
        assert!(a < self.agents);
        self.stats.writes += 1;
        let e = self.blocks.entry(block).or_default();
        let mut m = Messages::default();
        if e.owner == Some(a) {
            self.stats.hits += 1;
            return m; // already M/E: silent upgrade
        }
        m.dir_req = 1;
        if let Some(out) = sink.as_mut() {
            out.push(ProtocolMsg { kind: MsgKind::DirReq, src: CohEndpoint::Agent(a), dst: CohEndpoint::Home });
        }
        // invalidate all other holders
        let mut inv = 0;
        if let Some(o) = e.owner.take() {
            if o != a {
                inv += 1;
                m.data = 1; // dirty data forwarded
                self.stats.cache_to_cache += 1;
                if let Some(out) = sink.as_mut() {
                    out.push(ProtocolMsg { kind: MsgKind::Intervention, src: CohEndpoint::Home, dst: CohEndpoint::Agent(o) });
                    out.push(ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Agent(o), dst: CohEndpoint::Agent(a) });
                    out.push(ProtocolMsg { kind: MsgKind::Ack, src: CohEndpoint::Agent(o), dst: CohEndpoint::Agent(a) });
                }
            }
        }
        for &s in e.sharers.iter() {
            if s == a {
                continue;
            }
            inv += 1;
            if let Some(out) = sink.as_mut() {
                out.push(ProtocolMsg { kind: MsgKind::Intervention, src: CohEndpoint::Home, dst: CohEndpoint::Agent(s) });
                out.push(ProtocolMsg { kind: MsgKind::Ack, src: CohEndpoint::Agent(s), dst: CohEndpoint::Agent(a) });
            }
        }
        let had_data = m.data > 0;
        if !had_data {
            m.data = 1; // from memory
            if let Some(out) = sink.as_mut() {
                out.push(ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Home, dst: CohEndpoint::Agent(a) });
            }
        }
        m.interventions = inv;
        m.acks = inv.max(1);
        if inv == 0 {
            // nothing to invalidate: the single ack is the completion
            // notice back to the directory
            if let Some(out) = sink.as_mut() {
                out.push(ProtocolMsg { kind: MsgKind::Ack, src: CohEndpoint::Agent(a), dst: CohEndpoint::Home });
            }
        }
        self.stats.invalidations += inv as u64;
        e.sharers.clear();
        e.owner = Some(a);
        self.stats.messages += m.total() as u64;
        m
    }

    /// Evict `block` from `agent` (capacity/conflict): silent for S/E,
    /// writeback message for M (approximated as always-writeback for owner).
    pub fn evict(&mut self, a: usize, block: u64) -> Messages {
        self.evict_inner(a, block, None)
    }

    /// Like [`evict`](Directory::evict), additionally appending the
    /// writeback message (if any) to `out`.
    pub fn evict_routed(&mut self, a: usize, block: u64, out: &mut Vec<ProtocolMsg>) -> Messages {
        self.evict_inner(a, block, Some(out))
    }

    fn evict_inner(&mut self, a: usize, block: u64, sink: Option<&mut Vec<ProtocolMsg>>) -> Messages {
        let mut m = Messages::default();
        if let Some(e) = self.blocks.get_mut(&block) {
            if e.owner == Some(a) {
                e.owner = None;
                m.data = 1; // writeback
                self.stats.messages += 1;
                if let Some(out) = sink {
                    out.push(ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Agent(a), dst: CohEndpoint::Home });
                }
            } else if let Some(pos) = e.sharers.iter().position(|&s| s == a) {
                // O(1) swap-remove instead of an O(n) retain scan; sharer
                // order is protocol-irrelevant (see BlockEntry)
                e.sharers.swap_remove(pos);
            }
            if e.owner.is_none() && e.sharers.is_empty() {
                self.blocks.remove(&block);
            }
        }
        m
    }

    /// Protocol invariants: single-writer-multiple-readers — every tracked
    /// block has an owner XOR a non-empty sharer set (never both, and
    /// empty entries are reclaimed, never retained) — plus no duplicate
    /// or out-of-range holders.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (b, e) in &self.blocks {
            if e.owner.is_some() && !e.sharers.is_empty() {
                return Err(format!("block {b:#x}: owner and sharers coexist"));
            }
            if e.owner.is_none() && e.sharers.is_empty() {
                return Err(format!("block {b:#x}: empty entry retained"));
            }
            let mut s = e.sharers.clone();
            s.sort();
            s.dedup();
            if s.len() != e.sharers.len() {
                return Err(format!("block {b:#x}: duplicate sharers"));
            }
            if s.last().is_some_and(|&m| m >= self.agents) {
                return Err(format!("block {b:#x}: bogus sharer"));
            }
            if let Some(o) = e.owner {
                if o >= self.agents {
                    return Err(format!("block {b:#x}: bogus owner {o}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_read_grants_exclusive() {
        let mut d = Directory::new(4);
        let m = d.read(0, 0x40);
        assert_eq!(m.dir_req, 1);
        assert_eq!(m.data, 1);
        assert_eq!(d.state_of(0, 0x40), MesiState::Modified); // owner (E)
        d.check_invariants().unwrap();
    }

    #[test]
    fn second_read_hits() {
        let mut d = Directory::new(4);
        d.read(0, 0x40);
        let m = d.read(0, 0x40);
        assert_eq!(m.total(), 0);
        assert_eq!(d.stats().hits, 1);
    }

    #[test]
    fn read_after_remote_write_is_cache_to_cache() {
        let mut d = Directory::new(4);
        d.write(0, 0x80);
        let m = d.read(1, 0x80);
        assert_eq!(m.interventions, 1, "owner must be downgraded");
        assert_eq!(d.stats().cache_to_cache, 1);
        assert_eq!(d.state_of(0, 0x80), MesiState::Shared);
        assert_eq!(d.state_of(1, 0x80), MesiState::Shared);
        d.check_invariants().unwrap();
    }

    #[test]
    fn write_invalidates_all_sharers() {
        let mut d = Directory::new(8);
        d.write(0, 0x100);
        d.read(1, 0x100);
        d.read(2, 0x100);
        d.read(3, 0x100);
        let m = d.write(4, 0x100);
        assert_eq!(m.interventions, 4, "4 holders to invalidate");
        for a in 0..4 {
            assert_eq!(d.state_of(a, 0x100), MesiState::Invalid);
        }
        assert_eq!(d.state_of(4, 0x100), MesiState::Modified);
        d.check_invariants().unwrap();
    }

    #[test]
    fn silent_upgrade_on_owned_block() {
        let mut d = Directory::new(2);
        d.write(0, 0x1);
        let m = d.write(0, 0x1);
        assert_eq!(m.total(), 0);
    }

    #[test]
    fn evict_owner_writes_back() {
        let mut d = Directory::new(2);
        d.write(0, 0x1);
        let m = d.evict(0, 0x1);
        assert_eq!(m.data, 1);
        assert_eq!(d.state_of(0, 0x1), MesiState::Invalid);
        // next reader gets it from memory, fresh E
        let m = d.read(1, 0x1);
        assert_eq!(m.interventions, 0);
    }

    #[test]
    fn ping_pong_traffic_grows() {
        // write ping-pong between two agents: every write costs messages
        let mut d = Directory::new(2);
        for i in 0..10 {
            let m = d.write(i % 2, 0x40);
            if i > 0 {
                assert!(m.total() >= 3, "ping-pong write {i} should cost messages");
            }
        }
        assert!(d.stats().invalidations >= 9);
        d.check_invariants().unwrap();
    }

    #[test]
    fn sharer_swap_remove_keeps_set_semantics() {
        let mut d = Directory::new(6);
        d.write(0, 0x40);
        for a in 1..6 {
            d.read(a, 0x40);
        }
        // evict a middle sharer: the remaining set must stay intact
        d.evict(2, 0x40);
        assert_eq!(d.state_of(2, 0x40), MesiState::Invalid);
        for a in [0, 1, 3, 4, 5] {
            assert_eq!(d.state_of(a, 0x40), MesiState::Shared, "agent {a} lost its copy");
        }
        d.check_invariants().unwrap();
    }

    // ------------------------------------------------------------------
    // fabric-backed (routed) mode
    // ------------------------------------------------------------------

    fn count_kind(msgs: &[ProtocolMsg], kind: MsgKind) -> u32 {
        msgs.iter().filter(|m| m.kind == kind).count() as u32
    }

    fn assert_routed_matches(msgs: &[ProtocolMsg], m: Messages) {
        assert_eq!(count_kind(msgs, MsgKind::DirReq), m.dir_req);
        assert_eq!(count_kind(msgs, MsgKind::Intervention), m.interventions);
        assert_eq!(count_kind(msgs, MsgKind::Data), m.data);
        assert_eq!(count_kind(msgs, MsgKind::Ack), m.acks);
        assert_eq!(msgs.len() as u32, m.total());
    }

    #[test]
    fn routed_read_miss_from_memory() {
        let mut d = Directory::new(4);
        let mut out = Vec::new();
        let m = d.read_routed(0, 0x40, &mut out);
        assert_routed_matches(&out, m);
        assert_eq!(out[0], ProtocolMsg { kind: MsgKind::DirReq, src: CohEndpoint::Agent(0), dst: CohEndpoint::Home });
        assert_eq!(out[1], ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Home, dst: CohEndpoint::Agent(0) });
    }

    #[test]
    fn routed_read_forwarded_from_owner() {
        let mut d = Directory::new(4);
        d.write(2, 0x80);
        let mut out = Vec::new();
        let m = d.read_routed(1, 0x80, &mut out);
        assert_routed_matches(&out, m);
        // data must travel cache-to-cache from the old owner
        assert!(out.contains(&ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Agent(2), dst: CohEndpoint::Agent(1) }));
    }

    #[test]
    fn routed_write_invalidation_fanout() {
        let mut d = Directory::new(8);
        d.read(1, 0x100);
        d.read(2, 0x100);
        d.read(3, 0x100);
        let mut out = Vec::new();
        let m = d.write_routed(0, 0x100, &mut out);
        assert_routed_matches(&out, m);
        // one intervention per sharer, each from the home
        for s in 1..=3 {
            assert!(out.contains(&ProtocolMsg { kind: MsgKind::Intervention, src: CohEndpoint::Home, dst: CohEndpoint::Agent(s) }));
            assert!(out.contains(&ProtocolMsg { kind: MsgKind::Ack, src: CohEndpoint::Agent(s), dst: CohEndpoint::Agent(0) }));
        }
        d.check_invariants().unwrap();
    }

    #[test]
    fn routed_hit_emits_nothing() {
        let mut d = Directory::new(2);
        d.write(0, 0x1);
        let mut out = Vec::new();
        let m = d.write_routed(0, 0x1, &mut out);
        assert_eq!(m.total(), 0);
        assert!(out.is_empty());
    }

    #[test]
    fn routed_evict_owner_writeback() {
        let mut d = Directory::new(2);
        d.write(0, 0x1);
        let mut out = Vec::new();
        let m = d.evict_routed(0, 0x1, &mut out);
        assert_routed_matches(&out, m);
        assert_eq!(out[0], ProtocolMsg { kind: MsgKind::Data, src: CohEndpoint::Agent(0), dst: CohEndpoint::Home });
    }

    #[test]
    fn routed_counts_match_plain_counts() {
        // the routed and plain state machines must be the same machine
        let mut plain = Directory::new(5);
        let mut routed = Directory::new(5);
        let mut rng = crate::util::Rng::new(31);
        let mut out = Vec::new();
        for _ in 0..500 {
            let a = rng.below(5) as usize;
            let b = rng.below(16);
            let op = rng.below(3);
            out.clear();
            let (mp, mr) = match op {
                0 => (plain.read(a, b), routed.read_routed(a, b, &mut out)),
                1 => (plain.write(a, b), routed.write_routed(a, b, &mut out)),
                _ => (plain.evict(a, b), routed.evict_routed(a, b, &mut out)),
            };
            assert_eq!(mp, mr);
            assert_routed_matches(&out, mr);
            routed.check_invariants().unwrap();
        }
    }
}
