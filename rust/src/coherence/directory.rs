//! Directory-based MESI coherence engine — the protocol semantics that
//! CXL.cache contributes to tier-1 (§4: "accelerators can directly access
//! remote memory at instruction-level granularity without software
//! involvement").
//!
//! One `Directory` tracks the global state of cache blocks across N agents
//! (accelerators). `read`/`write` drive the state machine and return the
//! *message count breakdown* of the transaction, from which the latency
//! model derives coherent-access cost (each message crosses the fabric).

use std::collections::HashMap;

/// Per-agent MESI state of a block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MesiState {
    Modified,
    Exclusive,
    Shared,
    Invalid,
}

/// Message counts incurred by one transaction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Messages {
    /// Requests to the home directory.
    pub dir_req: u32,
    /// Forwarded interventions / invalidations to other agents.
    pub interventions: u32,
    /// Data transfers (cache-to-cache or memory-to-cache).
    pub data: u32,
    /// Acks back to directory/requester.
    pub acks: u32,
}

impl Messages {
    pub fn total(&self) -> u32 {
        self.dir_req + self.interventions + self.data + self.acks
    }
}

/// Cumulative statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct DirStats {
    pub reads: u64,
    pub writes: u64,
    pub hits: u64,
    pub cache_to_cache: u64,
    pub invalidations: u64,
    pub messages: u64,
}

/// Directory state for one block.
#[derive(Clone, Debug, Default)]
struct BlockEntry {
    /// agents holding the block in S
    sharers: Vec<usize>,
    /// agent holding M/E, if any
    owner: Option<usize>,
}

/// A full-map directory over `agents` caches.
#[derive(Clone, Debug)]
pub struct Directory {
    agents: usize,
    blocks: HashMap<u64, BlockEntry>,
    stats: DirStats,
}

impl Directory {
    pub fn new(agents: usize) -> Directory {
        assert!(agents >= 1);
        Directory { agents, blocks: HashMap::new(), stats: DirStats::default() }
    }

    pub fn stats(&self) -> DirStats {
        self.stats
    }

    /// State of `block` at `agent`.
    pub fn state_of(&self, agent: usize, block: u64) -> MesiState {
        match self.blocks.get(&block) {
            None => MesiState::Invalid,
            Some(e) => {
                if e.owner == Some(agent) {
                    // we do not distinguish M/E externally; M is the
                    // conservative answer for an owned block
                    MesiState::Modified
                } else if e.sharers.contains(&agent) {
                    MesiState::Shared
                } else {
                    MesiState::Invalid
                }
            }
        }
    }

    /// Agent `a` reads `block`. Returns the protocol messages incurred.
    pub fn read(&mut self, a: usize, block: u64) -> Messages {
        assert!(a < self.agents);
        self.stats.reads += 1;
        let e = self.blocks.entry(block).or_default();
        let mut m = Messages::default();
        if e.owner == Some(a) || e.sharers.contains(&a) {
            // hit: no traffic
            self.stats.hits += 1;
            return m;
        }
        m.dir_req = 1;
        match e.owner {
            Some(o) => {
                // owner forwards data, downgrades to S
                m.interventions = 1;
                m.data = 1;
                m.acks = 1;
                e.sharers.push(o);
                e.sharers.push(a);
                e.owner = None;
                self.stats.cache_to_cache += 1;
            }
            None => {
                // from memory (home node)
                m.data = 1;
                if e.sharers.is_empty() {
                    // grant E
                    e.owner = Some(a);
                } else {
                    e.sharers.push(a);
                }
            }
        }
        self.stats.messages += m.total() as u64;
        m
    }

    /// Agent `a` writes `block`.
    pub fn write(&mut self, a: usize, block: u64) -> Messages {
        assert!(a < self.agents);
        self.stats.writes += 1;
        let e = self.blocks.entry(block).or_default();
        let mut m = Messages::default();
        if e.owner == Some(a) {
            self.stats.hits += 1;
            return m; // already M/E: silent upgrade
        }
        m.dir_req = 1;
        // invalidate all other holders
        let mut inv = 0;
        if let Some(o) = e.owner.take() {
            if o != a {
                inv += 1;
                m.data = 1; // dirty data forwarded
                self.stats.cache_to_cache += 1;
            }
        }
        inv += e.sharers.iter().filter(|&&s| s != a).count() as u32;
        let had_data = m.data > 0;
        if !had_data {
            m.data = 1; // from memory
        }
        m.interventions = inv;
        m.acks = inv.max(1);
        self.stats.invalidations += inv as u64;
        e.sharers.clear();
        e.owner = Some(a);
        self.stats.messages += m.total() as u64;
        m
    }

    /// Evict `block` from `agent` (capacity/conflict): silent for S/E,
    /// writeback message for M (approximated as always-writeback for owner).
    pub fn evict(&mut self, a: usize, block: u64) -> Messages {
        let mut m = Messages::default();
        if let Some(e) = self.blocks.get_mut(&block) {
            if e.owner == Some(a) {
                e.owner = None;
                m.data = 1; // writeback
                self.stats.messages += 1;
            } else {
                e.sharers.retain(|&s| s != a);
            }
            if e.owner.is_none() && e.sharers.is_empty() {
                self.blocks.remove(&block);
            }
        }
        m
    }

    /// Protocol invariant: a block with an owner has no sharers (SWMR).
    pub fn check_invariants(&self) -> Result<(), String> {
        for (b, e) in &self.blocks {
            if e.owner.is_some() && !e.sharers.is_empty() {
                return Err(format!("block {b:#x}: owner and sharers coexist"));
            }
            let mut s = e.sharers.clone();
            s.sort();
            s.dedup();
            if s.len() != e.sharers.len() {
                return Err(format!("block {b:#x}: duplicate sharers"));
            }
            if let Some(o) = e.owner {
                if o >= self.agents {
                    return Err(format!("block {b:#x}: bogus owner {o}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_read_grants_exclusive() {
        let mut d = Directory::new(4);
        let m = d.read(0, 0x40);
        assert_eq!(m.dir_req, 1);
        assert_eq!(m.data, 1);
        assert_eq!(d.state_of(0, 0x40), MesiState::Modified); // owner (E)
        d.check_invariants().unwrap();
    }

    #[test]
    fn second_read_hits() {
        let mut d = Directory::new(4);
        d.read(0, 0x40);
        let m = d.read(0, 0x40);
        assert_eq!(m.total(), 0);
        assert_eq!(d.stats().hits, 1);
    }

    #[test]
    fn read_after_remote_write_is_cache_to_cache() {
        let mut d = Directory::new(4);
        d.write(0, 0x80);
        let m = d.read(1, 0x80);
        assert_eq!(m.interventions, 1, "owner must be downgraded");
        assert_eq!(d.stats().cache_to_cache, 1);
        assert_eq!(d.state_of(0, 0x80), MesiState::Shared);
        assert_eq!(d.state_of(1, 0x80), MesiState::Shared);
        d.check_invariants().unwrap();
    }

    #[test]
    fn write_invalidates_all_sharers() {
        let mut d = Directory::new(8);
        d.write(0, 0x100);
        d.read(1, 0x100);
        d.read(2, 0x100);
        d.read(3, 0x100);
        let m = d.write(4, 0x100);
        assert_eq!(m.interventions, 4, "4 holders to invalidate");
        for a in 0..4 {
            assert_eq!(d.state_of(a, 0x100), MesiState::Invalid);
        }
        assert_eq!(d.state_of(4, 0x100), MesiState::Modified);
        d.check_invariants().unwrap();
    }

    #[test]
    fn silent_upgrade_on_owned_block() {
        let mut d = Directory::new(2);
        d.write(0, 0x1);
        let m = d.write(0, 0x1);
        assert_eq!(m.total(), 0);
    }

    #[test]
    fn evict_owner_writes_back() {
        let mut d = Directory::new(2);
        d.write(0, 0x1);
        let m = d.evict(0, 0x1);
        assert_eq!(m.data, 1);
        assert_eq!(d.state_of(0, 0x1), MesiState::Invalid);
        // next reader gets it from memory, fresh E
        let m = d.read(1, 0x1);
        assert_eq!(m.interventions, 0);
    }

    #[test]
    fn ping_pong_traffic_grows() {
        // write ping-pong between two agents: every write costs messages
        let mut d = Directory::new(2);
        for i in 0..10 {
            let m = d.write(i % 2, 0x40);
            if i > 0 {
                assert!(m.total() >= 3, "ping-pong write {i} should cost messages");
            }
        }
        assert!(d.stats().invalidations >= 9);
        d.check_invariants().unwrap();
    }
}
