//! Coherence substrate for the tier-1 contrast in §5 of the paper:
//!
//! * [`directory`] — a MESI directory protocol engine (the semantics CXL.cache
//!   provides at instruction granularity): real state machine, message
//!   counting, invariant checks — plus a fabric-backed mode that emits
//!   each protocol message with endpoints ([`directory::ProtocolMsg`]).
//! * [`traffic`] — the [`CoherenceTraffic`] source that routes those
//!   messages over the shared fabric backend, so coherent-access latency
//!   emerges from link contention (the `mixed` experiment's coherence
//!   class).
//! * [`software`] — the non-coherent XLink alternative: sharing beyond the
//!   static partition requires explicit software-managed page copies.
//!
//! The latency *parameters* these produce feed `memory::access`; the
//! protocol engine itself is also exercised directly by tests and the
//! coherence ablation bench.

pub mod directory;
pub mod software;
pub mod traffic;

pub use directory::{CohEndpoint, Directory, DirStats, MesiState, MsgKind, ProtocolMsg};
pub use software::SoftwareCopyModel;
pub use traffic::{CoherenceConfig, CoherenceTraffic};
