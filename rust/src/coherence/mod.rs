//! Coherence substrate for the tier-1 contrast in §5 of the paper:
//!
//! * [`directory`] — a MESI directory protocol engine (the semantics CXL.cache
//!   provides at instruction granularity): real state machine, message
//!   counting, invariant checks.
//! * [`software`] — the non-coherent XLink alternative: sharing beyond the
//!   static partition requires explicit software-managed page copies.
//!
//! The latency *parameters* these produce feed `memory::access`; the
//! protocol engine itself is also exercised directly by tests and the
//! coherence ablation bench.

pub mod directory;
pub mod software;

pub use directory::{Directory, DirStats, MesiState};
pub use software::SoftwareCopyModel;
