//! Fabric-backed coherence: a [`TrafficSource`] that drives the MESI
//! [`Directory`] with a synthetic sharing workload and turns every
//! protocol message (dir_req / intervention / data / ack) into a routed
//! fabric transaction between the requester, the block's home node, and
//! the holders. Coherent-access latency then *emerges* from link
//! contention — the contrast with the closed-form
//! `Messages::total() × hop_cost` model that cannot see cross-traffic.
//!
//! Message causality is respected per transaction: the dir-request must
//! complete before interventions fan out, interventions before the data
//! transfer, data before the acks. Each phase's messages fly
//! concurrently; an operation's latency is issue-to-last-ack.

use super::directory::{CohEndpoint, Directory, MsgKind, ProtocolMsg};
use crate::fabric::NodeId;
use crate::sim::{Pull, SourcedTx, TrafficClass, TrafficSource, Transaction};
use crate::util::stats::Welford;
use crate::util::Rng;
use std::collections::VecDeque;

/// Workload + protocol-cost knobs for [`CoherenceTraffic`].
#[derive(Clone, Copy, Debug)]
pub struct CoherenceConfig {
    /// Total coherent operations to issue.
    pub ops: u64,
    /// Distinct cache blocks in the shared working set.
    pub blocks: u64,
    /// Zipf skew of block popularity (0 = uniform; higher = more
    /// contention on hot blocks).
    pub zipf_theta: f64,
    /// Fraction of operations that are writes.
    pub write_frac: f64,
    /// Mean issue interarrival, ns (exponential, open loop up to
    /// `window`).
    pub mean_interarrival_ns: f64,
    /// Max concurrently outstanding operations.
    pub window: usize,
    /// Cache-line payload of a Data message, bytes.
    pub line_bytes: f64,
    /// Control-message size (dir_req / intervention / ack), bytes.
    pub ctrl_bytes: f64,
    /// Memory access time at the home node for Data to/from Home, ns.
    pub home_device_ns: f64,
    /// SRAM lookup for cache-to-cache Data, ns.
    pub cache_device_ns: f64,
}

impl Default for CoherenceConfig {
    fn default() -> Self {
        CoherenceConfig {
            ops: 10_000,
            blocks: 4096,
            zipf_theta: 0.9,
            write_frac: 0.3,
            mean_interarrival_ns: 500.0,
            window: 32,
            line_bytes: 64.0,
            ctrl_bytes: 16.0,
            home_device_ns: 130.0,
            cache_device_ns: 40.0,
        }
    }
}

/// One in-flight coherent operation: its message list and phase cursor.
#[derive(Clone)]
struct OpState {
    issued_at: f64,
    msgs: Vec<ProtocolMsg>,
    /// Index into [`PHASES`] of the currently flying phase.
    phase: usize,
    /// In-flight messages of the current phase.
    outstanding: u32,
    /// Home node of this operation's block.
    home: NodeId,
}

/// A message staged for emission.
#[derive(Clone)]
struct ReadyMsg {
    slot: u32,
    at: f64,
    msg: ProtocolMsg,
    home: NodeId,
}

/// Causal phase order within one coherent transaction.
const PHASES: [MsgKind; 4] = [MsgKind::DirReq, MsgKind::Intervention, MsgKind::Data, MsgKind::Ack];

/// The coherence traffic source (see module docs).
///
/// `Clone` snapshots the complete mutable state (directory, RNG cursor,
/// in-flight ops, staged messages, accumulators) — the basis of the
/// [`TrafficSource::checkpoint`] support that lets the optimistic
/// sharded backend roll this source back to an epoch barrier.
#[derive(Clone)]
pub struct CoherenceTraffic {
    dir: Directory,
    /// agent index -> fabric node.
    agents: Vec<NodeId>,
    /// block home = `homes[block % homes.len()]` (address-interleaved
    /// CXL home agents, the paper's memory-node role).
    homes: Vec<NodeId>,
    cfg: CoherenceConfig,
    rng: Rng,
    issued: u64,
    live_ops: usize,
    fabric_inflight: usize,
    next_issue_at: f64,
    ops: Vec<OpState>,
    free: Vec<u32>,
    ready: VecDeque<ReadyMsg>,
    msg_buf: Vec<ProtocolMsg>,
    op_latency: Welford,
    hits: u64,
    completed_ops: u64,
}

impl CoherenceTraffic {
    pub fn new(agents: Vec<NodeId>, homes: Vec<NodeId>, cfg: CoherenceConfig, seed: u64) -> CoherenceTraffic {
        assert!(!agents.is_empty(), "need at least one caching agent");
        assert!(!homes.is_empty(), "need at least one home node");
        assert!(cfg.window >= 1);
        let dir = Directory::new(agents.len());
        CoherenceTraffic {
            dir,
            agents,
            homes,
            cfg,
            rng: Rng::new(seed),
            issued: 0,
            live_ops: 0,
            fabric_inflight: 0,
            next_issue_at: 0.0,
            ops: Vec::new(),
            free: Vec::new(),
            ready: VecDeque::new(),
            msg_buf: Vec::new(),
            op_latency: Welford::new(),
            hits: 0,
            completed_ops: 0,
        }
    }

    /// End-to-end latency of completed coherent operations
    /// (issue-to-last-ack), ns.
    pub fn op_latency(&self) -> &Welford {
        &self.op_latency
    }

    /// Operations that hit locally and produced no fabric traffic.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn completed_ops(&self) -> u64 {
        self.completed_ops
    }

    /// The protocol engine (for invariant checks after a run).
    pub fn directory(&self) -> &Directory {
        &self.dir
    }

    fn node_of(&self, ep: CohEndpoint, home: NodeId) -> NodeId {
        match ep {
            CohEndpoint::Agent(i) => self.agents[i],
            CohEndpoint::Home => home,
        }
    }

    /// Queue the next non-empty phase of op `slot` at time `at`; if no
    /// phase remains, the op completes.
    fn enqueue_next_phase(&mut self, slot: u32, at: f64) {
        loop {
            let op = &self.ops[slot as usize];
            if op.phase >= PHASES.len() {
                // all phases flown: op complete
                self.op_latency.push(at - op.issued_at);
                self.completed_ops += 1;
                self.live_ops -= 1;
                self.free.push(slot);
                return;
            }
            let kind = PHASES[op.phase];
            let n = op.msgs.iter().filter(|m| m.kind == kind).count() as u32;
            if n == 0 {
                self.ops[slot as usize].phase += 1;
                continue;
            }
            let home = op.home;
            let msg_count = op.msgs.len();
            let op = &mut self.ops[slot as usize];
            op.outstanding = n;
            op.phase += 1;
            // index walk instead of a per-phase collect: ProtocolMsg is
            // Copy, so no allocation on the phase-advance path
            for k in 0..msg_count {
                let msg = self.ops[slot as usize].msgs[k];
                if msg.kind == kind {
                    self.ready.push_back(ReadyMsg { slot, at, msg, home });
                }
            }
            return;
        }
    }

    /// Start operations until one produces fabric traffic (hits are
    /// free); returns false when the op budget is exhausted.
    fn issue_until_traffic(&mut self, now: f64) -> bool {
        while self.issued < self.cfg.ops {
            let t = self.next_issue_at.max(now);
            self.next_issue_at = t + self.rng.exp(1.0 / self.cfg.mean_interarrival_ns);
            self.issued += 1;
            let a = self.rng.below(self.agents.len() as u64) as usize;
            let block = self.rng.zipf(self.cfg.blocks, self.cfg.zipf_theta);
            // the buffer moves into the op on a miss; hits hand it back
            let mut buf = std::mem::take(&mut self.msg_buf);
            if self.rng.f64() < self.cfg.write_frac {
                self.dir.write_routed(a, block, &mut buf);
            } else {
                self.dir.read_routed(a, block, &mut buf);
            }
            if buf.is_empty() {
                self.msg_buf = buf;
                self.hits += 1;
                continue;
            }
            let home = self.homes[(block % self.homes.len() as u64) as usize];
            let op = OpState { issued_at: t, msgs: buf, phase: 0, outstanding: 0, home };
            let slot = match self.free.pop() {
                Some(s) => {
                    self.ops[s as usize] = op;
                    s
                }
                None => {
                    self.ops.push(op);
                    (self.ops.len() - 1) as u32
                }
            };
            self.live_ops += 1;
            self.enqueue_next_phase(slot, t);
            return true;
        }
        false
    }
}

impl TrafficSource for CoherenceTraffic {
    fn class(&self) -> TrafficClass {
        TrafficClass::Coherence
    }

    fn pull(&mut self, now: f64) -> Pull {
        loop {
            if let Some(r) = self.ready.pop_front() {
                let src = self.node_of(r.msg.src, r.home);
                let dst = self.node_of(r.msg.dst, r.home);
                let (bytes, device_ns) = match r.msg.kind {
                    MsgKind::Data => {
                        let d = if r.msg.src == CohEndpoint::Home || r.msg.dst == CohEndpoint::Home {
                            self.cfg.home_device_ns
                        } else {
                            self.cfg.cache_device_ns
                        };
                        (self.cfg.line_bytes, d)
                    }
                    _ => (self.cfg.ctrl_bytes, 0.0),
                };
                self.fabric_inflight += 1;
                return Pull::Tx(SourcedTx::new(
                    Transaction { src, dst, at: r.at.max(now), bytes, device_ns },
                    r.slot as u64,
                ));
            }
            if self.issued >= self.cfg.ops {
                return if self.fabric_inflight > 0 { Pull::Blocked } else { Pull::Done };
            }
            if self.live_ops >= self.cfg.window {
                debug_assert!(self.fabric_inflight > 0);
                return Pull::Blocked;
            }
            // reactive messages must not queue behind a staged future
            // issue: while traffic is in flight, wait for completions
            // instead of staging the next open-loop op early
            if self.next_issue_at > now && self.fabric_inflight > 0 {
                return Pull::Blocked;
            }
            if !self.issue_until_traffic(now) {
                return if self.fabric_inflight > 0 { Pull::Blocked } else { Pull::Done };
            }
        }
    }

    fn on_complete(&mut self, token: u64, now: f64) {
        self.fabric_inflight -= 1;
        let slot = token as u32;
        let op = &mut self.ops[slot as usize];
        debug_assert!(op.outstanding > 0);
        op.outstanding -= 1;
        if op.outstanding == 0 {
            self.enqueue_next_phase(slot, now);
        }
    }

    /// Every protocol message flies between a caching agent and either
    /// another agent or the block's home — all drawn from the fixed
    /// `agents` ∪ `homes` set, so the footprint is static and the source
    /// is eligible for coupled-domain shard pinning.
    fn footprint(&self) -> Option<Vec<NodeId>> {
        let mut nodes = self.agents.clone();
        for &h in &self.homes {
            if !nodes.contains(&h) {
                nodes.push(h);
            }
        }
        Some(nodes)
    }

    fn checkpointable(&self) -> bool {
        true
    }

    fn checkpoint(&self) -> Option<Box<dyn std::any::Any + Send>> {
        Some(Box::new(self.clone()))
    }

    fn restore(&mut self, snap: &(dyn std::any::Any + Send)) {
        let snap = snap.downcast_ref::<CoherenceTraffic>().expect("snapshot type mismatch");
        self.clone_from(snap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, LinkKind, NodeKind, Topology};
    use crate::sim::MemSim;

    fn rack(n: usize) -> (Fabric, Vec<NodeId>) {
        let t = Topology::single_hop(n, LinkKind::CxlCoherent, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        (Fabric::new(t), accs)
    }

    fn run(cfg: CoherenceConfig, seed: u64) -> (CoherenceTraffic, crate::sim::StreamReport) {
        let (f, accs) = rack(8);
        let homes = vec![accs[7]]; // last endpoint doubles as the home
        let agents = accs[..7].to_vec();
        let mut src = CoherenceTraffic::new(agents, homes, cfg, seed);
        let mut sim = MemSim::new(&f);
        let rep = {
            let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
            sim.run_streamed(&mut sources)
        };
        (src, rep)
    }

    #[test]
    fn ops_complete_and_invariants_hold() {
        let cfg = CoherenceConfig { ops: 500, window: 8, ..Default::default() };
        let (src, rep) = run(cfg, 7);
        assert_eq!(src.completed_ops() + src.hits(), 500);
        assert!(rep.total.completed > 0);
        assert_eq!(rep.class(TrafficClass::Coherence).completed, rep.total.completed);
        src.directory().check_invariants().unwrap();
        assert!(src.op_latency().count() == src.completed_ops());
        // every op pays at least a request + data round over the fabric
        assert!(src.op_latency().min() > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = CoherenceConfig { ops: 300, ..Default::default() };
        let (a, ra) = run(cfg, 11);
        let (b, rb) = run(cfg, 11);
        assert_eq!(ra.total.completed, rb.total.completed);
        assert!((ra.total.makespan_ns - rb.total.makespan_ns).abs() < 1e-12);
        assert!((a.op_latency().mean() - b.op_latency().mean()).abs() < 1e-12);
    }

    #[test]
    fn contended_blocks_cost_more_than_private() {
        // uniform over many blocks (mostly private) vs extreme skew on
        // few blocks (ping-pong): skew must raise per-op latency
        let private = CoherenceConfig { ops: 800, blocks: 1 << 20, zipf_theta: 0.0, ..Default::default() };
        let shared = CoherenceConfig { ops: 800, blocks: 4, zipf_theta: 0.0, write_frac: 0.5, ..Default::default() };
        let (p, _) = run(private, 3);
        let (s, _) = run(shared, 3);
        assert!(
            s.op_latency().mean() > p.op_latency().mean(),
            "shared {} !> private {}",
            s.op_latency().mean(),
            p.op_latency().mean()
        );
    }
}
