//! The non-coherent alternative (§5 tier-1 discussion): XLink unifies
//! addresses but *"such unified memory lacks protocol-level coherence.
//! Thus, sharing data beyond static partitions requires explicit
//! software-managed copying."*
//!
//! This model prices that software path: a runtime launch + page-granular
//! copy over the XLink fabric, amortized over the accesses that reuse the
//! copied page.

/// Cost model for software-managed remote access over non-coherent XLink.
#[derive(Clone, Copy, Debug)]
pub struct SoftwareCopyModel {
    /// Software/launch overhead per copy operation (driver call, source
    /// synchronization), ns. RDMA-like paths are higher; intra-rack XLink
    /// copies still pay a kernel-launch-ish cost.
    pub sw_overhead_ns: f64,
    /// Copy granularity, bytes (page).
    pub page_bytes: f64,
    /// Fabric bandwidth available to the copy, bytes/ns.
    pub copy_bw: f64,
    /// Fabric one-way latency for the copy command + first data, ns.
    pub fabric_latency_ns: f64,
    /// Mean number of accesses that reuse one copied page before it is
    /// re-fetched (temporal locality of the workload).
    pub reuse_per_page: f64,
}

impl SoftwareCopyModel {
    /// Default intra-rack XLink software-copy model.
    pub fn xlink_intra_rack() -> Self {
        SoftwareCopyModel {
            sw_overhead_ns: 1_500.0, // driver + stream sync
            page_bytes: 4096.0,
            copy_bw: 100.0,
            fabric_latency_ns: 400.0,
            // memory-intensive workloads (KV cache, embeddings, RAG) are
            // sparse: few accesses reuse a copied 4 KiB page (Fig 7 regime)
            reuse_per_page: 2.0,
        }
    }

    /// RDMA-based inter-cluster software copy (the scale-out baseline):
    /// higher software overhead (communicator sync, registration,
    /// serialization — §6: "InfiniBand-based RDMA communications inherently
    /// incur significant software overheads").
    pub fn rdma_inter_cluster() -> Self {
        SoftwareCopyModel {
            sw_overhead_ns: 8_000.0, // registration + sync + staging for remote reads
            page_bytes: 4096.0,
            copy_bw: 50.0,
            fabric_latency_ns: 1_800.0,
            reuse_per_page: 2.0,
        }
    }

    /// Cost of one page copy, ns.
    pub fn copy_ns(&self) -> f64 {
        self.sw_overhead_ns + self.fabric_latency_ns + self.page_bytes / self.copy_bw
    }

    /// Amortized per-access latency, ns: each access pays the copy cost
    /// divided by the page's reuse count.
    pub fn per_access_ns(&self) -> f64 {
        self.copy_ns() / self.reuse_per_page.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_cost_dominated_by_software() {
        let m = SoftwareCopyModel::xlink_intra_rack();
        // the point of the paper: even on fast XLink wires, software
        // overhead dominates the per-copy cost
        let wire = m.page_bytes / m.copy_bw;
        assert!(m.sw_overhead_ns > 10.0 * wire);
    }

    #[test]
    fn rdma_worse_than_xlink() {
        assert!(
            SoftwareCopyModel::rdma_inter_cluster().per_access_ns()
                > 2.0 * SoftwareCopyModel::xlink_intra_rack().per_access_ns()
        );
    }

    #[test]
    fn reuse_amortizes() {
        let mut m = SoftwareCopyModel::xlink_intra_rack();
        let lo = m.per_access_ns();
        m.reuse_per_page = 64.0;
        assert!(m.per_access_ns() < lo / 4.0);
    }

    #[test]
    fn zero_reuse_clamped() {
        let mut m = SoftwareCopyModel::xlink_intra_rack();
        m.reuse_per_page = 0.0;
        assert_eq!(m.per_access_ns(), m.copy_ns());
    }
}
