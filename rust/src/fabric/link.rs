//! Link models for every interconnect in the paper (Table 1 + §2).
//!
//! Per-message one-way latency over one link:
//!
//! ```text
//!   t(msg) = propagation + phy + packetization(flits) + serialization
//!          = prop_ns + phy.latency_ns()
//!            + flit_overhead_ns * n_flits(first-flit pipelining: only the
//!              head flit's framing is exposed; subsequent flits stream)
//!            + wire_bytes(msg) / (raw_bw * phy.efficiency())
//! ```
//!
//! Defaults are assembled from the paper's stated characteristics (NVLink
//! < 500 ns, UALink sub-µs @ 100 GB/s/port, CXL "medium (ns)") and public
//! specs; they are *parameters*, not constants — every experiment can
//! override them (DESIGN.md §2, substitution table).

use super::flit::FlitFormat;
use super::phy::Phy;

/// Interconnect technology of a link.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// NVLink 5 (GB200-class): proprietary PHY, 48–272 B flits.
    NvLink5,
    /// UALink 200: Ethernet PHY, fixed 640 B flits, 100 GB/s per port.
    UaLink,
    /// CXL 3.x coherence-centric configuration (CXL.cache traffic).
    CxlCoherent,
    /// CXL 3.x capacity-oriented configuration (CXL.mem / CXL.io bulk).
    CxlCapacity,
    /// PCIe Gen5 x16 (CPU attach in UALink clusters).
    PcieGen5,
    /// InfiniBand NDR 400 (the RDMA scale-out baseline).
    InfiniBandNdr,
}

/// Full parameter set of a link instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkParams {
    pub kind: LinkKind,
    /// Raw unidirectional bandwidth, bytes/ns (== GB/s).
    pub raw_bw: f64,
    /// Propagation + link-layer logic latency, one way, ns.
    pub prop_ns: f64,
    /// Head-flit framing/arbitration overhead, ns.
    pub flit_overhead_ns: f64,
    pub phy: Phy,
    pub flit: FlitFormat,
}

impl LinkKind {
    /// Default parameters (see module docs for provenance).
    pub fn params(self) -> LinkParams {
        match self {
            // 2 bonded NVLink5 ports: 100 GB/s/dir; <500 ns device-device
            LinkKind::NvLink5 => LinkParams {
                kind: self,
                raw_bw: 100.0,
                prop_ns: 80.0,
                flit_overhead_ns: 5.0,
                phy: Phy::Proprietary,
                flit: FlitFormat::new(240.0, 16.0, 16.0), // 256 B flit
            },
            // UALink 200: 100 GB/s per port, sub-µs end to end
            LinkKind::UaLink => LinkParams {
                kind: self,
                raw_bw: 100.0,
                prop_ns: 120.0,
                flit_overhead_ns: 8.0,
                phy: Phy::Ethernet,
                flit: FlitFormat::new(608.0, 32.0, 16.0), // fixed 640 B flit
            },
            // CXL 3.x over PCIe6 x16 (128 GB/s), 256 B PBR flits.
            // Coherence-centric: trimmed CXL.cache pipeline (paper §5 tier-1)
            LinkKind::CxlCoherent => LinkParams {
                kind: self,
                raw_bw: 128.0,
                prop_ns: 110.0,
                flit_overhead_ns: 6.0,
                phy: Phy::Pcie,
                flit: FlitFormat::new(236.0, 20.0, 16.0),
            },
            // Capacity-oriented: same wires, deeper controller (paper §5
            // tier-2; CXL.cache/io selectively disabled at endpoints)
            LinkKind::CxlCapacity => LinkParams {
                kind: self,
                raw_bw: 128.0,
                prop_ns: 140.0,
                flit_overhead_ns: 6.0,
                phy: Phy::Pcie,
                flit: FlitFormat::new(236.0, 20.0, 16.0),
            },
            LinkKind::PcieGen5 => LinkParams {
                kind: self,
                raw_bw: 64.0,
                prop_ns: 150.0,
                flit_overhead_ns: 10.0,
                phy: Phy::Pcie,
                flit: FlitFormat::new(256.0, 24.0, 20.0),
            },
            // InfiniBand NDR 4x: 50 GB/s; hardware port latency only —
            // RDMA *software* overhead lives in collective::rdma
            LinkKind::InfiniBandNdr => LinkParams {
                kind: self,
                raw_bw: 50.0,
                prop_ns: 250.0,
                flit_overhead_ns: 10.0,
                phy: Phy::InfiniBand,
                flit: FlitFormat::new(4096.0, 66.0, 30.0), // 4 KiB MTU
            },
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LinkKind::NvLink5 => "NVLink-5",
            LinkKind::UaLink => "UALink-200",
            LinkKind::CxlCoherent => "CXL-3.x (coherence-centric)",
            LinkKind::CxlCapacity => "CXL-3.x (capacity-oriented)",
            LinkKind::PcieGen5 => "PCIe-Gen5-x16",
            LinkKind::InfiniBandNdr => "InfiniBand-NDR",
        }
    }

    /// Table 1 "Coherence" row.
    pub fn coherence(self) -> &'static str {
        match self {
            LinkKind::NvLink5 => "Limited coherence",
            LinkKind::UaLink => "Non-coherent",
            LinkKind::CxlCoherent | LinkKind::CxlCapacity => "Cache-coherent",
            LinkKind::PcieGen5 => "Non-coherent",
            LinkKind::InfiniBandNdr => "Non-coherent",
        }
    }

    /// Table 1 "Topology" row.
    pub fn topology_class(self) -> &'static str {
        match self {
            LinkKind::NvLink5 | LinkKind::UaLink => "Single-hop",
            LinkKind::CxlCoherent | LinkKind::CxlCapacity => "Flexible fabric",
            LinkKind::PcieGen5 => "Tree",
            LinkKind::InfiniBandNdr => "Multi-hop network",
        }
    }

    /// Is this an accelerator-centric link (XLink in the paper's terms)?
    pub fn is_xlink(self) -> bool {
        matches!(self, LinkKind::NvLink5 | LinkKind::UaLink)
    }

    pub fn is_cxl(self) -> bool {
        matches!(self, LinkKind::CxlCoherent | LinkKind::CxlCapacity)
    }
}

impl LinkParams {
    /// Effective payload bandwidth (bytes/ns) after PHY + packetization
    /// overheads, for a given message size.
    pub fn effective_bw(&self, msg_bytes: f64) -> f64 {
        self.raw_bw * self.phy.efficiency() * self.flit.efficiency(msg_bytes)
    }

    /// One-way latency of a message over this single link, ns.
    pub fn message_latency_ns(&self, msg_bytes: f64) -> f64 {
        let wire = self.flit.wire_bytes(msg_bytes);
        let serialization = wire / (self.raw_bw * self.phy.efficiency());
        self.prop_ns + self.phy.latency_ns() + self.flit_overhead_ns + serialization
    }

    /// Latency of the head flit only (cut-through forwarding: used per-hop
    /// for multi-hop paths where serialization is pipelined across hops).
    pub fn head_latency_ns(&self) -> f64 {
        let head_wire = self.flit.payload_bytes + self.flit.header_bytes;
        self.prop_ns
            + self.phy.latency_ns()
            + self.flit_overhead_ns
            + head_wire / (self.raw_bw * self.phy.efficiency())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvlink_cacheline_under_500ns() {
        // Table 1: NVLink "very low (ns)" — paper quotes < 500 ns
        let p = LinkKind::NvLink5.params();
        let t = p.message_latency_ns(256.0);
        assert!(t < 500.0, "NVLink 256B latency {t} ns");
    }

    #[test]
    fn ualink_sub_microsecond() {
        let p = LinkKind::UaLink.params();
        let t = p.message_latency_ns(640.0);
        assert!(t < 1_000.0, "UALink 640B latency {t} ns");
        assert!(t > LinkKind::NvLink5.params().message_latency_ns(640.0));
    }

    #[test]
    fn cxl_medium_latency_ordering() {
        // Table 1 ordering: NVLink < CXL-ish band, CXL below IB hardware path
        let nv = LinkKind::NvLink5.params().message_latency_ns(256.0);
        let cxl = LinkKind::CxlCoherent.params().message_latency_ns(256.0);
        let ib = LinkKind::InfiniBandNdr.params().message_latency_ns(256.0);
        assert!(nv < cxl && cxl < ib, "nv={nv} cxl={cxl} ib={ib}");
    }

    #[test]
    fn capacity_cxl_trades_latency_for_simplicity() {
        let coh = LinkKind::CxlCoherent.params().message_latency_ns(4096.0);
        let cap = LinkKind::CxlCapacity.params().message_latency_ns(4096.0);
        assert!(cap > coh);
    }

    #[test]
    fn serialization_dominates_large_messages() {
        let p = LinkKind::UaLink.params();
        let t1 = p.message_latency_ns(1e6);
        // 1 MB at ~94 GB/s effective ≈ 10.6 µs; fixed part is ~0.2 µs
        assert!(t1 > 10_000.0 && t1 < 13_000.0, "{t1}");
    }

    #[test]
    fn effective_bw_below_raw() {
        for k in [
            LinkKind::NvLink5,
            LinkKind::UaLink,
            LinkKind::CxlCoherent,
            LinkKind::CxlCapacity,
            LinkKind::PcieGen5,
            LinkKind::InfiniBandNdr,
        ] {
            let p = k.params();
            assert!(p.effective_bw(1e6) < p.raw_bw);
            assert!(p.effective_bw(1e6) > 0.75 * p.raw_bw);
        }
    }

    #[test]
    fn head_latency_less_than_full_message() {
        let p = LinkKind::UaLink.params();
        assert!(p.head_latency_ns() < p.message_latency_ns(100_000.0));
    }

    #[test]
    fn xlink_classification() {
        assert!(LinkKind::NvLink5.is_xlink());
        assert!(LinkKind::UaLink.is_xlink());
        assert!(!LinkKind::CxlCoherent.is_xlink());
        assert!(LinkKind::CxlCapacity.is_cxl());
    }
}
