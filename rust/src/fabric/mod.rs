//! The interconnect substrate: links (NVLink/UALink/CXL/PCIe/InfiniBand),
//! PHY + flit-level packetization latency models, switches with PBR
//! routing, and topology builders (single-hop XLink domains; multi-level
//! Clos, 3D-torus and DragonFly CXL fabrics — Figure 4a of the paper).
//!
//! The paper's methodology (§6): *"link latency derived from flit sizes,
//! PHY layer characteristics, and packetization and queuing behaviors at
//! both link and transaction layers; switch latencies ... empirical
//! measurements from silicon prototypes, factoring in hop counts"* — this
//! module implements exactly those factors as a parameterized model.

pub mod link;
pub mod phy;
pub mod flit;
pub mod switch;
pub mod topology;
pub mod routing;
pub mod fabric;

pub use fabric::Fabric;
pub use link::{LinkKind, LinkParams};
pub use routing::{Path, Router};
pub use switch::SwitchParams;
pub use topology::{NodeId, NodeKind, Topology, TopologyKind};
