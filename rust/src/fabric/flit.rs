//! Flit-level packetization (Table 1 / §2: 640 B UALink flits, 48–272 B
//! NVLink flits, 256 B CXL 3.x PBR flits, 4 KiB InfiniBand MTU).
//!
//! A message of `payload` bytes is carved into flits of `payload_bytes`
//! with `header_bytes` of framing each; the wire carries
//! `n_flits * (payload_bytes + header_bytes)` plus a per-message header.

/// Flit format of a link protocol.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlitFormat {
    /// Usable payload per flit, bytes.
    pub payload_bytes: f64,
    /// Framing (header + CRC) per flit, bytes.
    pub header_bytes: f64,
    /// Per-message header/trailer (transaction-layer), bytes.
    pub msg_header_bytes: f64,
}

impl FlitFormat {
    pub const fn new(payload: f64, header: f64, msg_header: f64) -> Self {
        FlitFormat { payload_bytes: payload, header_bytes: header, msg_header_bytes: msg_header }
    }

    /// Number of flits for a message payload.
    pub fn flits(&self, payload: f64) -> f64 {
        ((payload + self.msg_header_bytes) / self.payload_bytes).ceil().max(1.0)
    }

    /// Total wire bytes for a message payload (packetization overhead in).
    pub fn wire_bytes(&self, payload: f64) -> f64 {
        let n = self.flits(payload);
        n * (self.payload_bytes + self.header_bytes)
    }

    /// Packetization efficiency payload/wire for a message size.
    pub fn efficiency(&self, payload: f64) -> f64 {
        payload / self.wire_bytes(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const UALINK: FlitFormat = FlitFormat::new(608.0, 32.0, 16.0); // 640 B flit
    const NVLINK: FlitFormat = FlitFormat::new(240.0, 16.0, 16.0); // 256 B flit

    #[test]
    fn single_flit_minimum() {
        assert_eq!(UALINK.flits(1.0), 1.0);
        assert_eq!(UALINK.flits(0.0), 1.0);
    }

    #[test]
    fn flit_count_scales() {
        // 608 payload bytes per flit, 16 msg header: 1200 B -> ceil(1216/608)=2
        assert_eq!(UALINK.flits(1200.0), 2.0);
        assert_eq!(NVLINK.flits(1200.0), 6.0); // ceil(1216/240)
    }

    #[test]
    fn small_messages_are_inefficient_on_big_flits() {
        // the paper's motivation for NVLink's small flits: fine-grained
        // traffic wastes a 640 B UALink flit
        let small = 64.0;
        assert!(UALINK.efficiency(small) < NVLINK.efficiency(small));
    }

    #[test]
    fn large_messages_approach_format_efficiency() {
        let eff = UALINK.efficiency(1e6);
        assert!(eff > 0.93 && eff < 0.951, "{eff}");
    }

    #[test]
    fn wire_bytes_monotone() {
        let mut last = 0.0;
        for sz in [1.0, 100.0, 640.0, 1000.0, 10_000.0] {
            let w = UALINK.wire_bytes(sz);
            assert!(w >= last);
            last = w;
        }
    }
}
