//! Topology graph: endpoints (accelerators, CPUs, memory nodes) and
//! switches joined by typed links, plus builders for the fabric shapes in
//! Figure 4a: single-hop XLink domains, multi-level Clos, 3D-torus and
//! DragonFly CXL fabrics.

use super::link::{LinkKind, LinkParams};
use super::switch::SwitchParams;

/// Index of a node in a [`Topology`].
pub type NodeId = usize;

/// What a node is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// An accelerator endpoint (GPU or other XPU).
    Accelerator,
    /// A host CPU endpoint.
    Cpu,
    /// A CPU-less / accelerator-less tier-2 memory node (paper §5).
    MemoryNode,
    /// A switch (XLink crossbar or CXL PBR switch).
    Switch,
}

/// A node in the fabric graph.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    /// Switch parameters if kind == Switch.
    pub switch: Option<SwitchParams>,
    /// Free-form label for printing/debugging ("cluster0/gpu13").
    pub label: String,
}

/// An undirected link between two nodes.
#[derive(Clone, Debug)]
pub struct Link {
    pub a: NodeId,
    pub b: NodeId,
    pub params: LinkParams,
}

/// The fabric shape classes of Figure 4a.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    SingleHop,
    MultiLevelClos,
    Torus3d,
    DragonFly,
}

/// A typed interconnect graph.
#[derive(Clone, Debug, Default)]
pub struct Topology {
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// adjacency: node -> (neighbor, link index)
    adj: Vec<Vec<(NodeId, usize)>>,
}

impl Topology {
    pub fn new() -> Self {
        Topology::default()
    }

    pub fn add_node(&mut self, kind: NodeKind, label: impl Into<String>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { kind, switch: None, label: label.into() });
        self.adj.push(Vec::new());
        id
    }

    pub fn add_switch(&mut self, params: SwitchParams, label: impl Into<String>) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { kind: NodeKind::Switch, switch: Some(params), label: label.into() });
        self.adj.push(Vec::new());
        id
    }

    pub fn connect(&mut self, a: NodeId, b: NodeId, kind: LinkKind) -> usize {
        self.connect_params(a, b, kind.params())
    }

    pub fn connect_params(&mut self, a: NodeId, b: NodeId, params: LinkParams) -> usize {
        assert!(a < self.nodes.len() && b < self.nodes.len() && a != b);
        let idx = self.links.len();
        self.links.push(Link { a, b, params });
        self.adj[a].push((b, idx));
        self.adj[b].push((a, idx));
        idx
    }

    pub fn neighbors(&self, n: NodeId) -> &[(NodeId, usize)] {
        &self.adj[n]
    }

    pub fn node(&self, n: NodeId) -> &Node {
        &self.nodes[n]
    }

    pub fn link(&self, l: usize) -> &Link {
        &self.links[l]
    }

    /// Node ids of a given kind.
    pub fn nodes_of(&self, kind: NodeKind) -> Vec<NodeId> {
        (0..self.nodes.len()).filter(|&i| self.nodes[i].kind == kind).collect()
    }

    /// Degree (port usage) of a node.
    pub fn degree(&self, n: NodeId) -> usize {
        self.adj[n].len()
    }

    /// Check no switch exceeds its radix.
    pub fn validate_radix(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if let Some(sw) = &n.switch {
                if self.degree(i) > sw.radix {
                    return Err(format!(
                        "switch {} ({}) degree {} exceeds radix {}",
                        i,
                        n.label,
                        self.degree(i),
                        sw.radix
                    ));
                }
            }
        }
        Ok(())
    }

    /// True if the graph is connected (ignoring isolated zero-degree nodes
    /// is NOT allowed — every node must be reachable from node 0).
    pub fn is_connected(&self) -> bool {
        if self.nodes.is_empty() {
            return true;
        }
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(n) = stack.pop() {
            for &(m, _) in &self.adj[n] {
                if !seen[m] {
                    seen[m] = true;
                    count += 1;
                    stack.push(m);
                }
            }
        }
        count == self.nodes.len()
    }

    /// Partition the nodes into at most `max_domains` topology-derived
    /// domains for sharded simulation: every endpoint joins the subtree of
    /// its first switch neighbor (its rack crossbar / CXL leaf), switches
    /// anchor their own subtree, and the subtrees are packed in node-id
    /// order into balanced domains. Returns one dense domain id per node
    /// (`0..k`, `k <= max_domains`); deterministic for a given topology.
    pub fn partition_domains(&self, max_domains: usize) -> Vec<u32> {
        let n = self.nodes.len();
        let max_domains = max_domains.max(1);
        if n == 0 {
            return Vec::new();
        }
        let anchor = self.domain_anchors();
        let mut size = vec![0usize; n];
        for &a in &anchor {
            size[a] += 1;
        }
        let anchors: Vec<usize> = (0..n).filter(|&i| size[i] > 0).collect();
        let k = max_domains.min(anchors.len()).max(1);
        // pack subtrees (ascending anchor id) into k bins of ~equal node
        // count; a bin closes once it reaches the target share
        let target = n.div_ceil(k);
        let mut bin_of = vec![0u32; n];
        let mut bin = 0usize;
        let mut acc = 0usize;
        for &a in &anchors {
            bin_of[a] = bin as u32;
            acc += size[a];
            if acc >= target && bin + 1 < k {
                bin += 1;
                acc = 0;
            }
        }
        (0..n).map(|i| bin_of[anchor[i]]).collect()
    }

    /// Like [`partition_domains`](Topology::partition_domains), but with
    /// the *coupled-domain* constraint pass used by reactive sharding:
    /// every node group in `groups` (a reactive source's footprint closed
    /// over its path link owners) is guaranteed to land inside a single
    /// domain. Touched switch subtrees are merged with a union-find
    /// before packing, and the merged components — which can be very
    /// uneven — are packed with an LPT (longest-processing-time) pass
    /// into at most `max_domains` balanced bins. Returns one dense domain
    /// id per node; deterministic for a given topology and group list.
    pub fn partition_domains_coupled(&self, max_domains: usize, groups: &[Vec<NodeId>]) -> Vec<u32> {
        let n = self.nodes.len();
        let max_domains = max_domains.max(1);
        if n == 0 {
            return Vec::new();
        }
        let anchor = self.domain_anchors();
        let mut size = vec![0usize; n];
        for &a in &anchor {
            size[a] += 1;
        }
        // union-find over anchors; every footprint's subtrees collapse
        // into one component
        fn find(parent: &mut [usize], mut x: usize) -> usize {
            while parent[x] != x {
                parent[x] = parent[parent[x]]; // path halving
                x = parent[x];
            }
            x
        }
        let mut parent: Vec<usize> = (0..n).collect();
        for g in groups {
            if let Some((&first, rest)) = g.split_first() {
                let root = find(&mut parent, anchor[first]);
                for &m in rest {
                    let r = find(&mut parent, anchor[m]);
                    parent[r] = root;
                }
            }
        }
        // component weight (node count) and min-anchor id, keyed by root
        let anchors: Vec<usize> = (0..n).filter(|&i| size[i] > 0).collect();
        let mut cweight = vec![0usize; n];
        let mut cmin = vec![usize::MAX; n];
        for &a in &anchors {
            let r = find(&mut parent, a);
            cweight[r] += size[a];
            cmin[r] = cmin[r].min(a);
        }
        let mut comps: Vec<usize> = (0..n).filter(|&i| cweight[i] > 0).collect();
        let k = max_domains.min(comps.len()).max(1);
        // LPT: heaviest component first (min-anchor tiebreak for
        // determinism), each into the currently least-loaded bin. The
        // first k components seed k distinct bins, so ids stay dense.
        comps.sort_by(|&a, &b| cweight[b].cmp(&cweight[a]).then(cmin[a].cmp(&cmin[b])));
        let mut load = vec![0usize; k];
        let mut bin_of_root = vec![0u32; n];
        for &c in &comps {
            let bin = (0..k).min_by_key(|&b| (load[b], b)).unwrap();
            bin_of_root[c] = bin as u32;
            load[bin] += cweight[c];
        }
        (0..n).map(|i| bin_of_root[find(&mut parent, anchor[i])]).collect()
    }

    /// Like [`partition_domains_coupled`](Topology::partition_domains_coupled),
    /// but instead of letting a fabric-spanning group silently collapse
    /// the partition into one domain, identifies *which* groups span it.
    /// While the coupled partition yields a single domain, the largest
    /// remaining group (most members; lowest index on ties) is marked
    /// spanning and excluded, and the partition recomputed — the greedy
    /// inverse of the union-find merge: the biggest footprint is the one
    /// gluing the domains together. Returns the domain assignment
    /// computed over the non-spanning groups only, plus one spanning
    /// flag per input group (all `false` when the topology itself is a
    /// single domain — nothing to blame on a footprint). Deterministic
    /// for a given topology and group list.
    pub fn partition_domains_coupled_spanning(
        &self,
        max_domains: usize,
        groups: &[Vec<NodeId>],
    ) -> (Vec<u32>, Vec<bool>) {
        let single = |doms: &[u32]| doms.iter().all(|&d| d == doms.first().copied().unwrap_or(0));
        let base = self.partition_domains_coupled(max_domains, &[]);
        if single(&base) {
            return (base, vec![false; groups.len()]);
        }
        let mut spanning = vec![false; groups.len()];
        loop {
            let active: Vec<Vec<NodeId>> = groups
                .iter()
                .zip(&spanning)
                .filter(|&(g, &s)| !s && !g.is_empty())
                .map(|(g, _)| g.clone())
                .collect();
            let doms = self.partition_domains_coupled(max_domains, &active);
            if !single(&doms) {
                return (doms, spanning);
            }
            let victim = (0..groups.len())
                .filter(|&i| !spanning[i] && !groups[i].is_empty())
                .max_by_key(|&i| (groups[i].len(), std::cmp::Reverse(i)))
                .expect("partition is multi-domain once every group is excluded");
            spanning[victim] = true;
        }
    }

    /// The switch subtree each node belongs to: switches anchor
    /// themselves; an endpoint joins its first switch neighbor (its rack
    /// crossbar / CXL leaf), or itself when it has none.
    fn domain_anchors(&self) -> Vec<usize> {
        (0..self.nodes.len())
            .map(|i| {
                if self.nodes[i].kind == NodeKind::Switch {
                    i
                } else {
                    self.neighbors(i)
                        .iter()
                        .find(|&&(m, _)| self.nodes[m].kind == NodeKind::Switch)
                        .map(|&(m, _)| m)
                        .unwrap_or(i)
                }
            })
            .collect()
    }

    // ------------------------------------------------------------------
    // builders (Figure 4a fabric shapes)
    // ------------------------------------------------------------------

    /// Single-hop XLink domain: `n` accelerators through one crossbar
    /// switch complex (one-stage Clos) — the intra-cluster shape (§4).
    pub fn single_hop(n: usize, kind: LinkKind, label: &str) -> Topology {
        let mut t = Topology::new();
        let sw = t.add_switch(SwitchParams::for_link(kind), format!("{label}/xswitch"));
        for i in 0..n {
            let a = t.add_node(NodeKind::Accelerator, format!("{label}/acc{i}"));
            t.connect(a, sw, kind);
        }
        t
    }

    /// Multi-level Clos over `leaves` leaf switches with `spines` spine
    /// switches; endpoints are attached later by the caller. Returns
    /// (topology, leaf switch ids).
    pub fn clos(leaves: usize, spines: usize, kind: LinkKind, label: &str) -> (Topology, Vec<NodeId>) {
        let mut t = Topology::new();
        let spine_ids: Vec<NodeId> = (0..spines)
            .map(|i| t.add_switch(SwitchParams::for_link(kind), format!("{label}/spine{i}")))
            .collect();
        let leaf_ids: Vec<NodeId> = (0..leaves)
            .map(|i| t.add_switch(SwitchParams::for_link(kind), format!("{label}/leaf{i}")))
            .collect();
        for &l in &leaf_ids {
            for &s in &spine_ids {
                t.connect(l, s, kind);
            }
        }
        (t, leaf_ids)
    }

    /// 3D-torus of switches with dimensions (x, y, z). Returns (topology,
    /// switch grid in x-major order).
    pub fn torus3d(dims: (usize, usize, usize), kind: LinkKind, label: &str) -> (Topology, Vec<NodeId>) {
        let (x, y, z) = dims;
        assert!(x >= 1 && y >= 1 && z >= 1);
        let mut t = Topology::new();
        let idx = |i: usize, j: usize, k: usize| (i * y + j) * z + k;
        let ids: Vec<NodeId> = (0..x * y * z)
            .map(|n| t.add_switch(SwitchParams::for_link(kind), format!("{label}/sw{n}")))
            .collect();
        for i in 0..x {
            for j in 0..y {
                for k in 0..z {
                    let me = ids[idx(i, j, k)];
                    // +1 neighbor in each dimension (wrap); avoid double
                    // connecting rings of length 2
                    if x > 1 && (i + 1 < x || x > 2) {
                        t.connect(me, ids[idx((i + 1) % x, j, k)], kind);
                    }
                    if y > 1 && (j + 1 < y || y > 2) {
                        t.connect(me, ids[idx(i, (j + 1) % y, k)], kind);
                    }
                    if z > 1 && (k + 1 < z || z > 2) {
                        t.connect(me, ids[idx(i, j, (k + 1) % z)], kind);
                    }
                }
            }
        }
        (t, ids)
    }

    /// DragonFly: `groups` groups of `per_group` switches; all-to-all
    /// within a group, one global link between every pair of groups.
    /// Returns (topology, per-group switch ids).
    pub fn dragonfly(groups: usize, per_group: usize, kind: LinkKind, label: &str) -> (Topology, Vec<Vec<NodeId>>) {
        let mut t = Topology::new();
        let mut gids = Vec::new();
        for g in 0..groups {
            let ids: Vec<NodeId> = (0..per_group)
                .map(|i| t.add_switch(SwitchParams::for_link(kind), format!("{label}/g{g}s{i}")))
                .collect();
            for i in 0..per_group {
                for j in i + 1..per_group {
                    t.connect(ids[i], ids[j], kind);
                }
            }
            gids.push(ids);
        }
        // global links: group g connects to group h via switch (h-1) mod per_group
        for g in 0..groups {
            for h in g + 1..groups {
                let sg = gids[g][h % per_group];
                let sh = gids[h][g % per_group];
                t.connect(sg, sh, kind);
            }
        }
        (t, gids)
    }

    /// Merge another topology into this one; returns the node id offset.
    pub fn merge(&mut self, other: &Topology) -> usize {
        let off = self.nodes.len();
        for n in &other.nodes {
            let id = self.nodes.len();
            self.nodes.push(n.clone());
            self.adj.push(Vec::new());
            debug_assert_eq!(id, off + (id - off));
        }
        for l in &other.links {
            self.connect_params(l.a + off, l.b + off, l.params);
        }
        off
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_hop_shape() {
        let t = Topology::single_hop(72, LinkKind::NvLink5, "rack0");
        assert_eq!(t.nodes_of(NodeKind::Accelerator).len(), 72);
        assert_eq!(t.nodes_of(NodeKind::Switch).len(), 1);
        assert!(t.is_connected());
        assert!(t.validate_radix().is_ok());
    }

    #[test]
    fn single_hop_radix_violation_detected() {
        let t = Topology::single_hop(200, LinkKind::NvLink5, "too-big");
        assert!(t.validate_radix().is_err(), "NVSwitch radix 144 must reject 200 GPUs");
    }

    #[test]
    fn clos_connects_all_leaves() {
        let (t, leaves) = Topology::clos(8, 4, LinkKind::CxlCoherent, "fab");
        assert_eq!(leaves.len(), 8);
        assert!(t.is_connected());
        assert_eq!(t.links.len(), 8 * 4);
    }

    #[test]
    fn torus_is_connected_and_regular() {
        let (t, ids) = Topology::torus3d((4, 4, 4), LinkKind::CxlCoherent, "torus");
        assert_eq!(ids.len(), 64);
        assert!(t.is_connected());
        for &id in &ids {
            assert_eq!(t.degree(id), 6, "interior torus switch must have degree 6");
        }
    }

    #[test]
    fn torus_degenerate_dims() {
        let (t, ids) = Topology::torus3d((2, 1, 1), LinkKind::CxlCoherent, "line");
        assert_eq!(ids.len(), 2);
        assert!(t.is_connected());
        assert_eq!(t.links.len(), 1, "2-ring must not double-link");
    }

    #[test]
    fn dragonfly_connected_with_global_links() {
        let (t, gids) = Topology::dragonfly(4, 4, LinkKind::CxlCoherent, "df");
        assert!(t.is_connected());
        assert_eq!(gids.len(), 4);
        // intra: 4 groups * C(4,2)=6 links; global: C(4,2)=6
        assert_eq!(t.links.len(), 4 * 6 + 6);
    }

    #[test]
    fn partition_single_hop_is_one_domain() {
        let t = Topology::single_hop(16, LinkKind::NvLink5, "r");
        let doms = t.partition_domains(8);
        assert_eq!(doms.len(), t.nodes.len());
        assert!(doms.iter().all(|&d| d == 0), "one crossbar subtree = one domain");
    }

    #[test]
    fn partition_clos_groups_leaf_subtrees() {
        let (mut t, leaves) = Topology::clos(8, 2, LinkKind::CxlCoherent, "c");
        let mut eps = Vec::new();
        for (i, &l) in leaves.iter().enumerate() {
            for e in 0..4 {
                let n = t.add_node(NodeKind::Accelerator, format!("ep{i}-{e}"));
                t.connect(n, l, LinkKind::CxlCoherent);
                eps.push((n, l));
            }
        }
        let doms = t.partition_domains(4);
        let k = doms.iter().copied().max().unwrap() as usize + 1;
        assert!(k > 1 && k <= 4, "expected 2..=4 domains, got {k}");
        // ids are dense
        for d in 0..k as u32 {
            assert!(doms.iter().any(|&x| x == d), "domain {d} empty");
        }
        // every endpoint shares its leaf switch's domain (subtree integrity)
        for &(n, l) in &eps {
            assert_eq!(doms[n], doms[l], "endpoint {n} split from its leaf {l}");
        }
        // deterministic
        assert_eq!(doms, t.partition_domains(4));
    }

    #[test]
    fn partition_respects_max_domains() {
        let (t, _) = Topology::torus3d((4, 4, 4), LinkKind::CxlCoherent, "t");
        for max in [1, 2, 3, 7, 64, 1000] {
            let doms = t.partition_domains(max);
            let k = doms.iter().copied().max().unwrap() as usize + 1;
            assert!(k <= max.min(t.nodes.len()), "max {max}: got {k} domains");
        }
        assert!(t.partition_domains(1).iter().all(|&d| d == 0));
    }

    #[test]
    fn coupled_partition_colocates_groups() {
        let (mut t, leaves) = Topology::clos(8, 2, LinkKind::CxlCoherent, "c");
        let mut eps = Vec::new();
        for (i, &l) in leaves.iter().enumerate() {
            for e in 0..4 {
                let n = t.add_node(NodeKind::Accelerator, format!("ep{i}-{e}"));
                t.connect(n, l, LinkKind::CxlCoherent);
                eps.push(n);
            }
        }
        // couple one endpoint from leaf 0 with one from leaf 5: both
        // subtrees must land in the same domain
        let groups = vec![vec![eps[0], eps[5 * 4]]];
        let doms = t.partition_domains_coupled(4, &groups);
        assert_eq!(doms.len(), t.nodes.len());
        assert_eq!(doms[eps[0]], doms[eps[5 * 4]], "coupled group split across domains");
        assert_eq!(doms[eps[0]], doms[leaves[0]]);
        assert_eq!(doms[eps[5 * 4]], doms[leaves[5]]);
        let k = doms.iter().copied().max().unwrap() as usize + 1;
        assert!(k > 1 && k <= 4, "expected 2..=4 domains, got {k}");
        for d in 0..k as u32 {
            assert!(doms.iter().any(|&x| x == d), "domain {d} empty");
        }
        // subtree integrity still holds
        for (i, &l) in leaves.iter().enumerate() {
            for e in 0..4 {
                assert_eq!(doms[eps[i * 4 + e]], doms[l]);
            }
        }
        // deterministic
        assert_eq!(doms, t.partition_domains_coupled(4, &groups));
    }

    #[test]
    fn coupled_partition_balances_disjoint_groups() {
        // 8 disjoint leaf groups, LPT over 4 bins: 2 subtrees per bin
        let (mut t, leaves) = Topology::clos(8, 2, LinkKind::CxlCoherent, "c");
        let mut groups = Vec::new();
        for &l in &leaves {
            let mut g = Vec::new();
            for _ in 0..4 {
                let n = t.add_node(NodeKind::Accelerator, "ep");
                t.connect(n, l, LinkKind::CxlCoherent);
                g.push(n);
            }
            groups.push(g);
        }
        let doms = t.partition_domains_coupled(4, &groups);
        let k = doms.iter().copied().max().unwrap() as usize + 1;
        assert_eq!(k, 4);
        let mut per_bin = vec![0usize; k];
        for &l in &leaves {
            per_bin[doms[l] as usize] += 1;
        }
        assert!(per_bin.iter().all(|&c| c == 2), "LPT must spread 8 equal subtrees 2-per-bin: {per_bin:?}");
    }

    #[test]
    fn coupled_partition_fabric_wide_group_collapses() {
        let (mut t, leaves) = Topology::clos(4, 2, LinkKind::CxlCoherent, "c");
        let mut all = Vec::new();
        for &l in &leaves {
            let n = t.add_node(NodeKind::Accelerator, "ep");
            t.connect(n, l, LinkKind::CxlCoherent);
            all.push(n);
        }
        // one group spanning every leaf: endpoints all merge into a
        // single domain (spine singletons may still occupy others)
        let doms = t.partition_domains_coupled(4, &[all.clone()]);
        let d0 = doms[all[0]];
        assert!(all.iter().all(|&n| doms[n] == d0), "fabric-wide group must collapse to one domain");
    }

    #[test]
    fn spanning_groups_are_detected_and_excluded() {
        let (mut t, leaves) = Topology::clos(4, 2, LinkKind::CxlCoherent, "c");
        let mut eps = Vec::new();
        for &l in &leaves {
            for _ in 0..2 {
                let n = t.add_node(NodeKind::Accelerator, "ep");
                t.connect(n, l, LinkKind::CxlCoherent);
                eps.push(n);
            }
        }
        // per-leaf pair groups plus one fabric-wide group: only the wide
        // group spans; the rest still partition into multiple domains
        let mut groups: Vec<Vec<NodeId>> = eps.chunks(2).map(|c| c.to_vec()).collect();
        groups.push(eps.clone());
        let (doms, spanning) = t.partition_domains_coupled_spanning(4, &groups);
        assert_eq!(spanning, vec![false, false, false, false, true]);
        let k = doms.iter().copied().max().unwrap() as usize + 1;
        assert!(k >= 2, "non-spanning groups must keep a multi-domain partition");
        for g in &groups[..4] {
            assert_eq!(doms[g[0]], doms[g[1]], "pinned group split across domains");
        }
        // deterministic
        assert_eq!((doms, spanning), t.partition_domains_coupled_spanning(4, &groups));

        // a single-switch fabric is one domain by construction: nothing
        // is blamed on a footprint
        let s = Topology::single_hop(6, LinkKind::NvLink5, "r");
        let accs = s.nodes_of(NodeKind::Accelerator);
        let (sdoms, sspan) = s.partition_domains_coupled_spanning(4, &[accs]);
        assert!(sdoms.iter().all(|&d| d == 0));
        assert_eq!(sspan, vec![false]);
    }

    #[test]
    fn merge_preserves_structure() {
        let mut a = Topology::single_hop(4, LinkKind::NvLink5, "a");
        let b = Topology::single_hop(4, LinkKind::UaLink, "b");
        let off = a.merge(&b);
        assert_eq!(a.nodes.len(), 10);
        assert_eq!(a.links.len(), 8);
        assert!(!a.is_connected(), "merged islands are disjoint until bridged");
        // bridge the two switch nodes via CXL
        let sa = 0;
        let sb = off;
        a.connect(sa, sb, LinkKind::CxlCoherent);
        assert!(a.is_connected());
    }
}
