//! Routing over the fabric graph: BFS shortest paths and precomputed PBR
//! (port-based routing) tables — §2's "PBR allows traffic routing decisions
//! to be determined at each switch port".

use super::topology::{NodeId, Topology};
use std::collections::VecDeque;

/// A routed path: the node sequence and the link indices between them.
#[derive(Clone, Debug, PartialEq)]
pub struct Path {
    pub nodes: Vec<NodeId>,
    pub links: Vec<usize>,
}

impl Path {
    /// Number of link traversals.
    pub fn hops(&self) -> usize {
        self.links.len()
    }

    /// Number of switches traversed (excludes endpoints).
    pub fn switch_hops(&self, topo: &Topology) -> usize {
        self.nodes[1..self.nodes.len().saturating_sub(1)]
            .iter()
            .filter(|&&n| topo.node(n).switch.is_some())
            .count()
    }
}

/// Precomputed routing state for a topology.
#[derive(Clone, Debug)]
pub struct Router {
    /// next_hop[dst][node] = (next node, link idx) on the shortest path
    /// node -> dst, or usize::MAX when unreachable. This *is* the PBR
    /// table: each switch consults its own row for the destination.
    next: Vec<Vec<(NodeId, usize)>>,
}

const UNREACH: (NodeId, usize) = (usize::MAX, usize::MAX);

impl Router {
    /// Build routing tables with one BFS per destination. O(V * (V + E)):
    /// fine for rack/row-scale fabrics (thousands of nodes).
    pub fn build(topo: &Topology) -> Router {
        let n = topo.nodes.len();
        let mut next = vec![vec![UNREACH; n]; n];
        let mut queue = VecDeque::new();
        for dst in 0..n {
            let row = &mut next[dst];
            let mut seen = vec![false; n];
            seen[dst] = true;
            queue.clear();
            queue.push_back(dst);
            while let Some(u) = queue.pop_front() {
                for &(v, l) in topo.neighbors(u) {
                    if !seen[v] {
                        seen[v] = true;
                        // first-found hop v -> u is on a shortest path v -> dst
                        row[v] = (u, l);
                        queue.push_back(v);
                    }
                }
            }
        }
        Router { next }
    }

    /// Shortest path src -> dst, or None if unreachable.
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<Path> {
        if src == dst {
            return Some(Path { nodes: vec![src], links: vec![] });
        }
        let mut nodes = vec![src];
        let mut links = Vec::new();
        let mut cur = src;
        while cur != dst {
            let (nxt, link) = self.next[dst][cur];
            if nxt == usize::MAX {
                return None;
            }
            nodes.push(nxt);
            links.push(link);
            cur = nxt;
            if links.len() > self.next.len() {
                unreachable!("routing loop");
            }
        }
        Some(Path { nodes, links })
    }

    /// Hop count src -> dst (None if unreachable).
    pub fn hops(&self, src: NodeId, dst: NodeId) -> Option<usize> {
        self.path(src, dst).map(|p| p.hops())
    }

    /// Fill `out` with the link indices of the shortest path src -> dst
    /// without materializing the node list (hot-path variant used by the
    /// event simulator — §Perf). Returns false if unreachable.
    pub fn links_into(&self, src: NodeId, dst: NodeId, out: &mut Vec<usize>) -> bool {
        out.clear();
        let mut cur = src;
        while cur != dst {
            let (nxt, link) = self.next[dst][cur];
            if nxt == usize::MAX {
                out.clear();
                return false;
            }
            out.push(link);
            cur = nxt;
        }
        true
    }

    /// The PBR table row a switch would hold for `dst`: port (link index)
    /// to forward on, per possible current node.
    pub fn pbr_port(&self, at: NodeId, dst: NodeId) -> Option<usize> {
        if at == dst {
            return None;
        }
        let (nxt, link) = self.next[dst][at];
        if nxt == usize::MAX {
            None
        } else {
            Some(link)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::link::LinkKind;
    use crate::fabric::topology::NodeKind;

    #[test]
    fn single_hop_paths_are_two_links() {
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let r = Router::build(&t);
        let accs = t.nodes_of(NodeKind::Accelerator);
        let p = r.path(accs[0], accs[7]).unwrap();
        assert_eq!(p.hops(), 2); // acc -> switch -> acc
        assert_eq!(p.switch_hops(&t), 1);
    }

    #[test]
    fn self_path_is_empty() {
        let t = Topology::single_hop(4, LinkKind::NvLink5, "r");
        let r = Router::build(&t);
        let p = r.path(2, 2).unwrap();
        assert_eq!(p.hops(), 0);
    }

    #[test]
    fn unreachable_is_none() {
        let mut t = Topology::single_hop(2, LinkKind::NvLink5, "a");
        let lonely = t.add_node(NodeKind::MemoryNode, "island");
        let r = Router::build(&t);
        assert!(r.path(0, lonely).is_none());
        assert!(r.hops(lonely, 0).is_none());
    }

    #[test]
    fn clos_spine_routing() {
        let (mut t, leaves) = Topology::clos(4, 2, LinkKind::CxlCoherent, "f");
        // hang one endpoint off each leaf
        let mut eps = Vec::new();
        for (i, &l) in leaves.iter().enumerate() {
            let e = t.add_node(NodeKind::Accelerator, format!("ep{i}"));
            t.connect(e, l, LinkKind::CxlCoherent);
            eps.push(e);
        }
        let r = Router::build(&t);
        // ep -> leaf -> spine -> leaf -> ep = 4 links
        let p = r.path(eps[0], eps[3]).unwrap();
        assert_eq!(p.hops(), 4);
        assert_eq!(p.switch_hops(&t), 3);
    }

    #[test]
    fn torus_path_lengths_bounded_by_diameter() {
        let (t, ids) = Topology::torus3d((4, 4, 4), LinkKind::CxlCoherent, "t");
        let r = Router::build(&t);
        // torus diameter = sum(dim/2) = 6
        for &a in &[ids[0]] {
            for &b in ids.iter() {
                let h = r.hops(a, b).unwrap();
                assert!(h <= 6, "hops {h} exceeds torus diameter");
            }
        }
    }

    #[test]
    fn pbr_table_consistent_with_paths() {
        let (mut t, leaves) = Topology::clos(3, 2, LinkKind::CxlCoherent, "f");
        let e0 = t.add_node(NodeKind::Accelerator, "e0");
        let e1 = t.add_node(NodeKind::Accelerator, "e1");
        t.connect(e0, leaves[0], LinkKind::CxlCoherent);
        t.connect(e1, leaves[2], LinkKind::CxlCoherent);
        let r = Router::build(&t);
        let p = r.path(e0, e1).unwrap();
        // walking the PBR ports reproduces the path's links
        let mut cur = e0;
        for &l in &p.links {
            assert_eq!(r.pbr_port(cur, e1), Some(l));
            let link = t.link(l);
            cur = if link.a == cur { link.b } else { link.a };
        }
        assert_eq!(cur, e1);
    }

    #[test]
    fn dragonfly_diameter_small() {
        let (t, gids) = Topology::dragonfly(6, 4, LinkKind::CxlCoherent, "df");
        let r = Router::build(&t);
        for &a in &gids[0] {
            for g in &gids[1..] {
                for &b in g {
                    assert!(r.hops(a, b).unwrap() <= 3, "dragonfly switch-to-switch > 3 hops");
                }
            }
        }
    }
}
