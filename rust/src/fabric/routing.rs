//! Routing over the fabric graph: BFS shortest paths and precomputed PBR
//! (port-based routing) tables — §2's "PBR allows traffic routing decisions
//! to be determined at each switch port".
//!
//! # Performance architecture (§Perf)
//!
//! The PBR table is a single contiguous `Box<[(u32, u32)]>` indexed by
//! `(dst * n + node) * k + rail` (8 bytes/entry, one allocation) rather
//! than a nested `Vec<Vec<(usize, usize)>>` (16 bytes/entry plus a heap
//! row per destination). Construction runs one BFS per destination over a
//! CSR copy of the adjacency, with destinations partitioned across
//! `std::thread::scope` workers operating on disjoint row chunks — no
//! locks, no external deps. The BFS uses the table row itself as its
//! visited set (a row entry is written exactly when its node is first
//! discovered), so per-destination scratch is just a reused flat queue.
//!
//! Per-destination discovery order is identical to the pre-flattening
//! serial implementation (kept as [`reference::SerialRouter`] for parity
//! tests and the `benches/simscale.rs` baseline), so the produced paths
//! are byte-identical — parallelism is across destinations only.
//!
//! # Multipath (equal-cost rails)
//!
//! [`Router::build`] keeps the classic single-path table (`k = 1`, the
//! exact layout and contents above). [`Router::build_multipath`] widens
//! every `(dst, node)` cell to up to `K` equal-cost `(next, link)`
//! entries — still one contiguous allocation, still one BFS per
//! destination, which now records *all* shortest predecessors of a node
//! in BFS scan order instead of only the first. Rail 0 of every cell is
//! byte-identical to the single-path entry (pinned by
//! `tests/prop_invariants.rs::prop_deterministic_rail_matches_single_path`),
//! so [`Router::next_hop`] / [`Router::path`] / [`Router::links_into`]
//! are the rail-0 views and every existing caller behaves exactly as
//! before. Every candidate in a cell strictly decreases the hop distance
//! to `dst`, so *any* per-hop rail choice yields a shortest, loop-free
//! path — the invariant the rail selectors in [`crate::sim::rails`]
//! (deterministic / ECMP hash-spray / congestion-adaptive) rely on.

use super::topology::{NodeId, Topology};

/// A routed path: the node sequence and the link indices between them.
#[derive(Clone, Debug, PartialEq)]
pub struct Path {
    pub nodes: Vec<NodeId>,
    pub links: Vec<usize>,
}

impl Path {
    /// Number of link traversals.
    pub fn hops(&self) -> usize {
        self.links.len()
    }

    /// Number of switches traversed (excludes endpoints).
    pub fn switch_hops(&self, topo: &Topology) -> usize {
        self.nodes[1..self.nodes.len().saturating_sub(1)]
            .iter()
            .filter(|&&n| topo.node(n).switch.is_some())
            .count()
    }
}

/// Flat-table entry marking "no route" (also covers the diagonal
/// `next[dst * n + dst]`, which no lookup ever consults, and the unused
/// rail slots of multipath cells).
const UNREACH: (u32, u32) = (u32::MAX, u32::MAX);

/// Upper bound on rails per cell: the simulator packs the rail index
/// into 4 bits of its path-cache key (see `sim::memsim`), and equal-cost
/// fan-out beyond 16 buys nothing a hash over 16 rails does not.
pub const MAX_RAILS: usize = 16;

/// Precomputed routing state for a topology.
///
/// `next[(dst * n + node) * k + rail] = (next node, link idx)`, the
/// `rail`-th equal-cost shortest next hop node -> dst, or [`UNREACH`]
/// when unreachable / the cell holds fewer than `k` candidates. This
/// *is* the PBR table: each switch consults its own cell for the
/// destination. `k == 1` (from [`Router::build`]) is the classic
/// single-path table, byte-identical to the pre-multipath layout.
#[derive(Clone, Debug)]
pub struct Router {
    n: usize,
    /// Rails (equal-cost candidate entries) per `(dst, node)` cell.
    k: usize,
    next: Box<[(u32, u32)]>,
}

/// Adjacency in CSR form: one contiguous scan per node instead of a
/// nested-Vec pointer chase, shared read-only by all BFS workers.
struct Csr {
    off: Vec<u32>,
    adj: Vec<(u32, u32)>,
}

impl Csr {
    fn build(topo: &Topology) -> Csr {
        let n = topo.nodes.len();
        let mut off = vec![0u32; n + 1];
        for u in 0..n {
            off[u + 1] = off[u] + topo.neighbors(u).len() as u32;
        }
        let mut adj = Vec::with_capacity(off[n] as usize);
        for u in 0..n {
            for &(v, l) in topo.neighbors(u) {
                adj.push((v as u32, l as u32));
            }
        }
        Csr { off, adj }
    }
}

/// One BFS rooted at `dst`, writing next-hops into that destination's row.
/// The row doubles as the visited set: an entry is non-UNREACH exactly
/// when its node has been discovered (the root holds a sentinel during
/// the search and is restored to UNREACH afterwards, matching the
/// reference implementation's table byte-for-byte).
fn bfs_row(csr: &Csr, dst: usize, row: &mut [(u32, u32)], queue: &mut Vec<u32>) {
    row[dst] = (dst as u32, u32::MAX); // visited sentinel, never read back
    queue.clear();
    queue.push(dst as u32);
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head] as usize;
        head += 1;
        for &(v, l) in &csr.adj[csr.off[u] as usize..csr.off[u + 1] as usize] {
            let e = &mut row[v as usize];
            if *e == UNREACH {
                // first-found hop v -> u is on a shortest path v -> dst
                *e = (u as u32, l);
                queue.push(v);
            }
        }
    }
    row[dst] = UNREACH;
}

/// Multipath sibling of [`bfs_row`]: rail 0 of every cell is written at
/// first discovery exactly as the single-path BFS (same predecessor, same
/// link, same order), and every *additional* shortest predecessor found
/// later in the scan fills the next free rail slot, up to `k`.
///
/// `dist` is per-worker scratch that is deliberately never reset between
/// destinations: a node's distance is only ever read after the node was
/// discovered in the *current* BFS (the cell's rail-0 entry is the
/// visited test), and discovery always writes `dist` first — stale values
/// from earlier destinations are unreachable.
fn bfs_row_multi(
    csr: &Csr,
    dst: usize,
    k: usize,
    row: &mut [(u32, u32)],
    queue: &mut Vec<u32>,
    dist: &mut [u32],
) {
    row[dst * k] = (dst as u32, u32::MAX); // visited sentinel, never read back
    dist[dst] = 0;
    queue.clear();
    queue.push(dst as u32);
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head] as usize;
        head += 1;
        let du = dist[u];
        for &(v, l) in &csr.adj[csr.off[u] as usize..csr.off[u + 1] as usize] {
            let base = v as usize * k;
            if row[base] == UNREACH {
                // first-found hop v -> u is on a shortest path v -> dst
                row[base] = (u as u32, l);
                dist[v as usize] = du + 1;
                queue.push(v);
            } else if dist[v as usize] == du + 1 {
                // u is another predecessor of v at the same BFS level:
                // hop v -> u is an equal-cost shortest alternative
                for slot in &mut row[base + 1..base + k] {
                    if *slot == UNREACH {
                        *slot = (u as u32, l);
                        break;
                    }
                }
            }
        }
    }
    row[dst * k] = UNREACH;
}

impl Router {
    /// Build routing tables with one BFS per destination — O(V * (V + E))
    /// work, partitioned across all hardware threads (serial below 64
    /// nodes, where spawn overhead dominates).
    pub fn build(topo: &Topology) -> Router {
        Router::build_multipath(topo, 1)
    }

    /// Build with an explicit worker count, honored exactly (1 = serial;
    /// used by tests and the simscale bench to isolate the parallel
    /// speedup and to exercise the partitioning on small graphs).
    pub fn build_with_threads(topo: &Topology, threads: usize) -> Router {
        Router::build_multipath_with_threads(topo, 1, threads)
    }

    /// Build a multipath table holding up to `k` equal-cost next hops per
    /// `(dst, node)` cell (`k = 1` is exactly [`Router::build`]). Same
    /// parallel per-destination BFS, same thread heuristic.
    pub fn build_multipath(topo: &Topology, k: usize) -> Router {
        let n = topo.nodes.len();
        let threads = if n < 64 { 1 } else { crate::util::par::workers_for(n) };
        Router::build_multipath_with_threads(topo, k, threads)
    }

    /// As [`Router::build_multipath`] with an explicit worker count.
    pub fn build_multipath_with_threads(topo: &Topology, k: usize, threads: usize) -> Router {
        assert!(
            (1..=MAX_RAILS).contains(&k),
            "rail count {k} outside 1..={MAX_RAILS}"
        );
        let n = topo.nodes.len();
        if n == 0 {
            return Router { n, k, next: Vec::new().into_boxed_slice() };
        }
        let csr = Csr::build(topo);
        // (u32::MAX, u32::MAX) is an all-ones byte pattern: this fill
        // lowers to one memset-class pass over the table
        let mut next = vec![UNREACH; n * n * k].into_boxed_slice();
        let threads = threads.clamp(1, n);
        if threads == 1 {
            let mut queue = Vec::with_capacity(n);
            let mut dist = vec![0u32; if k > 1 { n } else { 0 }];
            for (dst, row) in next.chunks_mut(n * k).enumerate() {
                if k == 1 {
                    bfs_row(&csr, dst, row, &mut queue);
                } else {
                    bfs_row_multi(&csr, dst, k, row, &mut queue, &mut dist);
                }
            }
        } else {
            let rows_per = n.div_ceil(threads);
            std::thread::scope(|s| {
                for (w, chunk) in next.chunks_mut(rows_per * n * k).enumerate() {
                    let csr = &csr;
                    s.spawn(move || {
                        let mut queue = Vec::with_capacity(n);
                        let mut dist = vec![0u32; if k > 1 { n } else { 0 }];
                        for (i, row) in chunk.chunks_mut(n * k).enumerate() {
                            let dst = w * rows_per + i;
                            if k == 1 {
                                bfs_row(csr, dst, row, &mut queue);
                            } else {
                                bfs_row_multi(csr, dst, k, row, &mut queue, &mut dist);
                            }
                        }
                    });
                }
            });
        }
        Router { n, k, next }
    }

    /// Number of nodes the table covers.
    pub fn node_count(&self) -> usize {
        self.n
    }

    /// Rails (equal-cost entry slots) per cell this table was built with.
    #[inline]
    pub fn max_rails(&self) -> usize {
        self.k
    }

    /// All equal-cost `(next node, link)` candidates at `at` toward `dst`
    /// in raw table form (empty when `at == dst` or unreachable). Entry 0
    /// is the classic single-path PBR choice.
    #[inline]
    pub fn rail_entries(&self, at: NodeId, dst: NodeId) -> &[(u32, u32)] {
        if at == dst {
            return &[];
        }
        let base = (dst * self.n + at) * self.k;
        let cell = &self.next[base..base + self.k];
        // rail slots fill in order, so the first UNREACH ends the cell
        let len = cell.iter().position(|&e| e == UNREACH).unwrap_or(self.k);
        &cell[..len]
    }

    /// Number of equal-cost candidates at `at` toward `dst` (0 when
    /// `at == dst` or unreachable).
    #[inline]
    pub fn rails(&self, at: NodeId, dst: NodeId) -> usize {
        self.rail_entries(at, dst).len()
    }

    /// The `rail`-th equal-cost candidate `(next node, link)` at `at`
    /// toward `dst`, or None when the cell holds fewer rails.
    #[inline]
    pub fn rail_entry(&self, at: NodeId, dst: NodeId, rail: usize) -> Option<(NodeId, usize)> {
        self.rail_entries(at, dst).get(rail).map(|&(nxt, l)| (nxt as NodeId, l as usize))
    }

    /// Raw PBR entry: (next node, link) on the path `at -> dst`, or None
    /// when unreachable (or `at == dst`). The rail-0 view: byte-identical
    /// to the pre-multipath router.
    #[inline]
    pub fn next_hop(&self, at: NodeId, dst: NodeId) -> Option<(NodeId, usize)> {
        if at == dst {
            return None;
        }
        let (nxt, link) = self.next[(dst * self.n + at) * self.k];
        if nxt == u32::MAX {
            None
        } else {
            Some((nxt as NodeId, link as usize))
        }
    }

    /// Shortest path src -> dst, or None if unreachable (rail-0 view).
    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<Path> {
        if src == dst {
            return Some(Path { nodes: vec![src], links: vec![] });
        }
        let mut nodes = vec![src];
        let mut links = Vec::new();
        let mut cur = src;
        while cur != dst {
            let (nxt, link) = self.next_hop(cur, dst)?;
            nodes.push(nxt);
            links.push(link);
            cur = nxt;
            if links.len() > self.n {
                panic!(
                    "routing loop walking {src} -> {dst}: table cycled at node {cur} after {} hops",
                    links.len()
                );
            }
        }
        Some(Path { nodes, links })
    }

    /// Shortest path src -> dst following rail `rail`: at every node the
    /// candidate `rail % rails(node, dst)` is taken, so any rail index
    /// yields a shortest, loop-free path and rail 0 is [`Router::path`].
    pub fn path_rail(&self, src: NodeId, dst: NodeId, rail: usize) -> Option<Path> {
        if src == dst {
            return Some(Path { nodes: vec![src], links: vec![] });
        }
        let mut nodes = vec![src];
        let mut links = Vec::new();
        let mut cur = src;
        while cur != dst {
            let rails = self.rails(cur, dst);
            if rails == 0 {
                return None;
            }
            let (nxt, link) = self.rail_entry(cur, dst, rail % rails).expect("rails > 0");
            nodes.push(nxt);
            links.push(link);
            cur = nxt;
            if links.len() > self.n {
                panic!(
                    "routing loop walking rail {rail} of {src} -> {dst}: table cycled at node {cur} after {} hops",
                    links.len()
                );
            }
        }
        Some(Path { nodes, links })
    }

    /// Hop count src -> dst (None if unreachable), counted by walking the
    /// PBR table without materializing the node/link lists.
    pub fn hops(&self, src: NodeId, dst: NodeId) -> Option<usize> {
        let mut cur = src;
        let mut h = 0;
        while cur != dst {
            let (nxt, _) = self.next_hop(cur, dst)?;
            cur = nxt;
            h += 1;
            if h > self.n {
                panic!(
                    "routing loop walking {src} -> {dst}: table cycled at node {cur} after {h} hops"
                );
            }
        }
        Some(h)
    }

    /// Fill `out` with the link indices of the shortest path src -> dst
    /// without materializing the node list (hot-path variant used by the
    /// event simulator — §Perf). Returns false if unreachable.
    pub fn links_into(&self, src: NodeId, dst: NodeId, out: &mut Vec<usize>) -> bool {
        out.clear();
        let mut cur = src;
        while cur != dst {
            match self.next_hop(cur, dst) {
                Some((nxt, link)) => {
                    out.push(link);
                    cur = nxt;
                    if out.len() > self.n {
                        panic!(
                            "routing loop walking {src} -> {dst}: table cycled at node {cur} after {} hops",
                            out.len()
                        );
                    }
                }
                None => {
                    out.clear();
                    return false;
                }
            }
        }
        true
    }

    /// The PBR table row a switch would hold for `dst`: port (link index)
    /// to forward on, per possible current node.
    pub fn pbr_port(&self, at: NodeId, dst: NodeId) -> Option<usize> {
        self.next_hop(at, dst).map(|(_, link)| link)
    }
}

pub mod reference {
    //! The pre-flattening serial router, preserved verbatim as (a) the
    //! parity oracle for `tests/prop_invariants.rs` and (b) the seed
    //! baseline that `benches/simscale.rs` measures speedups against.
    //! Not used on any hot path.

    use super::{Path, Topology};
    use crate::fabric::topology::NodeId;
    use std::collections::VecDeque;

    const UNREACH: (NodeId, usize) = (usize::MAX, usize::MAX);

    /// Nested-table serial router: one BFS per destination into
    /// `Vec<Vec<(usize, usize)>>`.
    pub struct SerialRouter {
        next: Vec<Vec<(NodeId, usize)>>,
    }

    impl SerialRouter {
        pub fn build(topo: &Topology) -> SerialRouter {
            let n = topo.nodes.len();
            let mut next = vec![vec![UNREACH; n]; n];
            let mut queue = VecDeque::new();
            for dst in 0..n {
                let row = &mut next[dst];
                let mut seen = vec![false; n];
                seen[dst] = true;
                queue.clear();
                queue.push_back(dst);
                while let Some(u) = queue.pop_front() {
                    for &(v, l) in topo.neighbors(u) {
                        if !seen[v] {
                            seen[v] = true;
                            row[v] = (u, l);
                            queue.push_back(v);
                        }
                    }
                }
            }
            SerialRouter { next }
        }

        pub fn path(&self, src: NodeId, dst: NodeId) -> Option<Path> {
            if src == dst {
                return Some(Path { nodes: vec![src], links: vec![] });
            }
            let mut nodes = vec![src];
            let mut links = Vec::new();
            let mut cur = src;
            while cur != dst {
                let (nxt, link) = self.next[dst][cur];
                if nxt == usize::MAX {
                    return None;
                }
                nodes.push(nxt);
                links.push(link);
                cur = nxt;
                if links.len() > self.next.len() {
                    unreachable!("routing loop");
                }
            }
            Some(Path { nodes, links })
        }

        pub fn links_into(&self, src: NodeId, dst: NodeId, out: &mut Vec<usize>) -> bool {
            out.clear();
            let mut cur = src;
            while cur != dst {
                let (nxt, link) = self.next[dst][cur];
                if nxt == usize::MAX {
                    out.clear();
                    return false;
                }
                out.push(link);
                cur = nxt;
            }
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::link::LinkKind;
    use crate::fabric::topology::NodeKind;

    #[test]
    fn single_hop_paths_are_two_links() {
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let r = Router::build(&t);
        let accs = t.nodes_of(NodeKind::Accelerator);
        let p = r.path(accs[0], accs[7]).unwrap();
        assert_eq!(p.hops(), 2); // acc -> switch -> acc
        assert_eq!(p.switch_hops(&t), 1);
    }

    #[test]
    fn self_path_is_empty() {
        let t = Topology::single_hop(4, LinkKind::NvLink5, "r");
        let r = Router::build(&t);
        let p = r.path(2, 2).unwrap();
        assert_eq!(p.hops(), 0);
    }

    #[test]
    fn unreachable_is_none() {
        let mut t = Topology::single_hop(2, LinkKind::NvLink5, "a");
        let lonely = t.add_node(NodeKind::MemoryNode, "island");
        let r = Router::build(&t);
        assert!(r.path(0, lonely).is_none());
        assert!(r.hops(lonely, 0).is_none());
        let mut links = Vec::new();
        assert!(!r.links_into(0, lonely, &mut links));
        assert!(links.is_empty());
    }

    #[test]
    fn clos_spine_routing() {
        let (mut t, leaves) = Topology::clos(4, 2, LinkKind::CxlCoherent, "f");
        // hang one endpoint off each leaf
        let mut eps = Vec::new();
        for (i, &l) in leaves.iter().enumerate() {
            let e = t.add_node(NodeKind::Accelerator, format!("ep{i}"));
            t.connect(e, l, LinkKind::CxlCoherent);
            eps.push(e);
        }
        let r = Router::build(&t);
        // ep -> leaf -> spine -> leaf -> ep = 4 links
        let p = r.path(eps[0], eps[3]).unwrap();
        assert_eq!(p.hops(), 4);
        assert_eq!(p.switch_hops(&t), 3);
    }

    #[test]
    fn torus_path_lengths_bounded_by_diameter() {
        let (t, ids) = Topology::torus3d((4, 4, 4), LinkKind::CxlCoherent, "t");
        let r = Router::build(&t);
        // torus diameter = sum(dim/2) = 6
        for &a in &[ids[0]] {
            for &b in ids.iter() {
                let h = r.hops(a, b).unwrap();
                assert!(h <= 6, "hops {h} exceeds torus diameter");
            }
        }
    }

    #[test]
    fn pbr_table_consistent_with_paths() {
        let (mut t, leaves) = Topology::clos(3, 2, LinkKind::CxlCoherent, "f");
        let e0 = t.add_node(NodeKind::Accelerator, "e0");
        let e1 = t.add_node(NodeKind::Accelerator, "e1");
        t.connect(e0, leaves[0], LinkKind::CxlCoherent);
        t.connect(e1, leaves[2], LinkKind::CxlCoherent);
        let r = Router::build(&t);
        let p = r.path(e0, e1).unwrap();
        // walking the PBR ports reproduces the path's links
        let mut cur = e0;
        for &l in &p.links {
            assert_eq!(r.pbr_port(cur, e1), Some(l));
            let link = t.link(l);
            cur = if link.a == cur { link.b } else { link.a };
        }
        assert_eq!(cur, e1);
    }

    #[test]
    fn dragonfly_diameter_small() {
        let (t, gids) = Topology::dragonfly(6, 4, LinkKind::CxlCoherent, "df");
        let r = Router::build(&t);
        for &a in &gids[0] {
            for g in &gids[1..] {
                for &b in g {
                    assert!(r.hops(a, b).unwrap() <= 3, "dragonfly switch-to-switch > 3 hops");
                }
            }
        }
    }

    #[test]
    fn parallel_build_matches_serial_flat_build() {
        let (t, ids) = Topology::torus3d((4, 4, 4), LinkKind::CxlCoherent, "t");
        let par = Router::build_with_threads(&t, 4);
        let ser = Router::build_with_threads(&t, 1);
        assert_eq!(par.next, ser.next, "worker partitioning changed the table");
        for &a in &ids {
            for &b in &ids {
                assert_eq!(par.path(a, b), ser.path(a, b));
            }
        }
    }

    #[test]
    fn flat_build_matches_reference_serial_router() {
        let (mut t, leaves) = Topology::clos(5, 3, LinkKind::CxlCoherent, "f");
        let mut eps = Vec::new();
        for (i, &l) in leaves.iter().enumerate() {
            let e = t.add_node(NodeKind::Accelerator, format!("ep{i}"));
            t.connect(e, l, LinkKind::CxlCoherent);
            eps.push(e);
        }
        let flat = Router::build(&t);
        let seed = reference::SerialRouter::build(&t);
        for a in 0..t.nodes.len() {
            for b in 0..t.nodes.len() {
                assert_eq!(flat.path(a, b), seed.path(a, b), "paths diverge {a}->{b}");
            }
        }
    }

    /// A Clos leaf reaching a remote endpoint has one equal-cost rail per
    /// spine, and rail 0 is the classic single-path choice.
    #[test]
    fn multipath_rails_cover_clos_spines() {
        let (mut t, leaves) = Topology::clos(4, 3, LinkKind::CxlCoherent, "f");
        let mut eps = Vec::new();
        for (i, &l) in leaves.iter().enumerate() {
            let e = t.add_node(NodeKind::Accelerator, format!("ep{i}"));
            t.connect(e, l, LinkKind::CxlCoherent);
            eps.push(e);
        }
        let single = Router::build(&t);
        let multi = Router::build_multipath(&t, 4);
        assert_eq!(multi.max_rails(), 4);
        // leaf0 -> ep3 (behind leaf3): 3 spines, 3 equal-cost next hops
        assert_eq!(multi.rails(leaves[0], eps[3]), 3);
        assert_eq!(multi.next_hop(leaves[0], eps[3]), single.next_hop(leaves[0], eps[3]));
        // the endpoint itself has a single attach link: one rail
        assert_eq!(multi.rails(eps[0], eps[3]), 1);
        // every rail is a distinct spine and one hop closer to dst
        let h = multi.hops(leaves[0], eps[3]).unwrap();
        let mut nexts = std::collections::HashSet::new();
        for r in 0..multi.rails(leaves[0], eps[3]) {
            let (nxt, link) = multi.rail_entry(leaves[0], eps[3], r).unwrap();
            assert!(nexts.insert(nxt), "rail {r} repeats next hop {nxt}");
            assert_eq!(multi.hops(nxt, eps[3]).unwrap() + 1, h);
            let l = t.link(link);
            assert!(l.a == leaves[0] || l.b == leaves[0]);
        }
    }

    #[test]
    fn multipath_rail0_matches_single_path_build() {
        let (t, ids) = Topology::torus3d((3, 3, 2), LinkKind::CxlCoherent, "t");
        let single = Router::build(&t);
        let multi = Router::build_multipath(&t, 4);
        for &a in &ids {
            for &b in &ids {
                assert_eq!(multi.path(a, b), single.path(a, b), "rail-0 diverges {a}->{b}");
                assert_eq!(multi.path_rail(a, b, 0), single.path(a, b));
            }
        }
    }

    #[test]
    fn multipath_parallel_build_matches_serial_build() {
        let (t, _) = Topology::torus3d((4, 3, 2), LinkKind::CxlCoherent, "t");
        let par = Router::build_multipath_with_threads(&t, 4, 4);
        let ser = Router::build_multipath_with_threads(&t, 4, 1);
        assert_eq!(par.next, ser.next, "worker partitioning changed the multipath table");
    }

    #[test]
    fn multipath_rail_walks_are_shortest_and_loop_free() {
        // torus3d((4,4,1)) has two equal-cost directions around each ring
        let (t, ids) = Topology::torus3d((4, 4, 1), LinkKind::CxlCoherent, "t");
        let r = Router::build_multipath(&t, 4);
        let mut saw_diversity = false;
        for &a in &ids {
            for &b in &ids {
                let h = r.hops(a, b).unwrap();
                let mut distinct = std::collections::HashSet::new();
                for rail in 0..r.max_rails() {
                    let p = r.path_rail(a, b, rail).unwrap();
                    assert_eq!(p.hops(), h, "rail {rail} of {a}->{b} is not shortest");
                    let mut seen = std::collections::HashSet::new();
                    assert!(p.nodes.iter().all(|&n| seen.insert(n)), "rail {rail} loops");
                    distinct.insert(p.links.clone());
                }
                saw_diversity |= distinct.len() > 1;
            }
        }
        assert!(saw_diversity, "no pair on a 4x4 torus had rail diversity");
    }

    #[test]
    #[should_panic(expected = "outside 1..=")]
    fn multipath_rejects_zero_rails() {
        let t = Topology::single_hop(2, LinkKind::NvLink5, "r");
        Router::build_multipath(&t, 0);
    }
}
