//! `Fabric` facade: a topology + its router, answering endpoint-to-endpoint
//! questions — message latency (cut-through pipelined across hops), path
//! bandwidth, and load-adjusted queuing.

use super::link::LinkParams;
use super::routing::{Path, Router};
use super::topology::{NodeId, Topology};
use std::sync::Arc;

/// A topology with prebuilt routing and background-load knobs.
///
/// The routing table sits behind an [`Arc`]: cloning a `Fabric` (the
/// sweep-harness build-once pattern, see
/// [`MemSim::fork`](crate::sim::MemSim::fork)) shares the O(nodes²) PBR
/// table instead of copying it. The table is immutable between rebuilds —
/// [`Fabric::rebuild`] / [`Fabric::enable_multipath`] swap in a freshly
/// built `Arc`, never mutate through one.
#[derive(Clone, Debug)]
pub struct Fabric {
    pub topo: Topology,
    router: Arc<Router>,
    /// Background utilization per link (0..1) used by the analytic queuing
    /// adder; the event simulator models real contention instead.
    load: Vec<f64>,
}

/// Latency breakdown of one message transfer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// Head-of-line propagation+PHY+framing along every hop, ns.
    pub head_ns: f64,
    /// Switch traversal (incl. PBR decisions), ns.
    pub switch_ns: f64,
    /// Payload serialization at the bottleneck link, ns.
    pub serialization_ns: f64,
    /// Analytic queuing adder from background load, ns.
    pub queuing_ns: f64,
}

impl LatencyBreakdown {
    pub fn total_ns(&self) -> f64 {
        self.head_ns + self.switch_ns + self.serialization_ns + self.queuing_ns
    }
}

impl Fabric {
    /// Build a fabric over `topo`. Routing-table construction is the
    /// dominant cost at pod scale and runs one BFS per destination across
    /// all hardware threads into a flat PBR table (see
    /// [`crate::fabric::routing`] §Perf).
    pub fn new(topo: Topology) -> Fabric {
        let router = Arc::new(Router::build(&topo));
        let load = vec![0.0; topo.links.len()];
        Fabric { topo, router, load }
    }

    /// Rebuild routing after topology edits (preserves the current rail
    /// count, so a multipath-enabled fabric stays multipath).
    pub fn rebuild(&mut self) {
        self.router =
            Arc::new(Router::build_multipath(&self.topo, self.router.max_rails().max(1)));
        self.load.resize(self.topo.links.len(), 0.0);
    }

    /// Rebuild the PBR table with up to `k` equal-cost rails per cell
    /// (see [`crate::fabric::routing`] §Multipath). Rail 0 stays
    /// byte-identical to the single-path table, so analytic consumers
    /// ([`Fabric::path`], [`Fabric::latency_ns`], ...) are unchanged;
    /// the event simulator's rail selectors spread over the extra
    /// candidates. `k = 1` restores the classic single-path router.
    pub fn enable_multipath(&mut self, k: usize) {
        self.router = Arc::new(Router::build_multipath(&self.topo, k));
    }

    /// Rails per PBR cell of the current routing table (1 = single-path).
    pub fn max_rails(&self) -> usize {
        self.router.max_rails()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Hop count src -> dst, walked over the PBR table without
    /// materializing the node/link lists.
    pub fn hops(&self, src: NodeId, dst: NodeId) -> Option<usize> {
        self.router.hops(src, dst)
    }

    /// Set background utilization (0..1) on a link.
    pub fn set_load(&mut self, link: usize, rho: f64) {
        self.load[link] = rho.clamp(0.0, 0.99);
    }

    /// Uniform background utilization on all links.
    pub fn set_uniform_load(&mut self, rho: f64) {
        for l in self.load.iter_mut() {
            *l = rho.clamp(0.0, 0.99);
        }
    }

    pub fn path(&self, src: NodeId, dst: NodeId) -> Option<Path> {
        self.router.path(src, dst)
    }

    /// Bottleneck payload bandwidth along the path, bytes/ns.
    pub fn path_bandwidth(&self, path: &Path, msg_bytes: f64) -> f64 {
        path.links
            .iter()
            .map(|&l| self.topo.link(l).params.effective_bw(msg_bytes))
            .fold(f64::INFINITY, f64::min)
    }

    /// One-way latency of a `msg_bytes` message along `path`, with
    /// cut-through pipelining: per-hop head latency + per-switch traversal
    /// + one serialization of the full payload at the bottleneck link +
    /// per-hop queuing at the current background load.
    pub fn message_latency(&self, path: &Path, msg_bytes: f64) -> LatencyBreakdown {
        if path.links.is_empty() {
            return LatencyBreakdown::default();
        }
        let mut head = 0.0;
        let mut queuing = 0.0;
        let mut bottleneck: Option<&LinkParams> = None;
        for &l in &path.links {
            let p = &self.topo.link(l).params;
            head += p.head_latency_ns();
            let service = p.flit.wire_bytes(p.flit.payload_bytes) / (p.raw_bw * p.phy.efficiency());
            // queue at entry to each link, scaled by that link's load
            let rho = self.load[l];
            queuing += rho / (2.0 * (1.0 - rho)) * service * p.flit.flits(msg_bytes).min(64.0);
            if bottleneck.map(|b| p.effective_bw(msg_bytes) < b.effective_bw(msg_bytes)).unwrap_or(true) {
                bottleneck = Some(p);
            }
        }
        let mut switch_ns = 0.0;
        for &n in &path.nodes {
            if let Some(sw) = &self.topo.node(n).switch {
                switch_ns += sw.traversal_ns();
            }
        }
        let b = bottleneck.unwrap();
        // the head flit's wire time is already counted in head_latency
        let body_bytes = (b.flit.wire_bytes(msg_bytes)
            - (b.flit.payload_bytes + b.flit.header_bytes))
            .max(0.0);
        let serialization = body_bytes / (b.raw_bw * b.phy.efficiency());
        LatencyBreakdown { head_ns: head, switch_ns, serialization_ns: serialization, queuing_ns: queuing }
    }

    /// Convenience: end-to-end one-way latency (ns) between two nodes.
    pub fn latency_ns(&self, src: NodeId, dst: NodeId, msg_bytes: f64) -> Option<f64> {
        let p = self.path(src, dst)?;
        Some(self.message_latency(&p, msg_bytes).total_ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::link::LinkKind;
    use crate::fabric::topology::NodeKind;

    fn rack() -> (Fabric, Vec<NodeId>) {
        let t = Topology::single_hop(8, LinkKind::NvLink5, "r");
        let accs = t.nodes_of(NodeKind::Accelerator);
        (Fabric::new(t), accs)
    }

    #[test]
    fn intra_rack_small_message_sub_microsecond() {
        let (f, accs) = rack();
        let t = f.latency_ns(accs[0], accs[1], 256.0).unwrap();
        assert!(t < 1_000.0, "intra-rack 256 B took {t} ns");
    }

    #[test]
    fn zero_length_path_zero_latency() {
        let (f, accs) = rack();
        assert_eq!(f.latency_ns(accs[0], accs[0], 1e6).unwrap(), 0.0);
    }

    #[test]
    fn latency_monotone_in_size() {
        let (f, accs) = rack();
        let mut last = 0.0;
        for sz in [64.0, 1024.0, 65_536.0, 1e6, 1e8] {
            let t = f.latency_ns(accs[0], accs[1], sz).unwrap();
            assert!(t > last, "size {sz}: {t} !> {last}");
            last = t;
        }
    }

    #[test]
    fn more_hops_more_latency() {
        // chain: ep - sw - sw - sw - ep vs single switch
        let (mut t, leaves) = Topology::clos(2, 1, LinkKind::CxlCoherent, "f");
        let e0 = t.add_node(NodeKind::Accelerator, "e0");
        let e1 = t.add_node(NodeKind::Accelerator, "e1");
        t.connect(e0, leaves[0], LinkKind::CxlCoherent);
        t.connect(e1, leaves[1], LinkKind::CxlCoherent);
        let f = Fabric::new(t);
        let multi = f.latency_ns(e0, e1, 256.0).unwrap();

        let (f1, accs) = {
            let t = Topology::single_hop(2, LinkKind::CxlCoherent, "s");
            let a = t.nodes_of(NodeKind::Accelerator);
            (Fabric::new(t), a)
        };
        let single = f1.latency_ns(accs[0], accs[1], 256.0).unwrap();
        assert!(multi > single, "multi {multi} <= single {single}");
    }

    #[test]
    fn background_load_adds_queuing() {
        let (mut f, accs) = rack();
        let base = f.latency_ns(accs[0], accs[1], 4096.0).unwrap();
        f.set_uniform_load(0.8);
        let loaded = f.latency_ns(accs[0], accs[1], 4096.0).unwrap();
        assert!(loaded > base, "load must add queuing: {loaded} <= {base}");
    }

    #[test]
    fn serialization_pipelines_across_hops() {
        // for a large message, latency should be ~ one serialization, not
        // hops * serialization (cut-through)
        let (mut t, leaves) = Topology::clos(2, 1, LinkKind::CxlCoherent, "f");
        let e0 = t.add_node(NodeKind::Accelerator, "e0");
        let e1 = t.add_node(NodeKind::Accelerator, "e1");
        t.connect(e0, leaves[0], LinkKind::CxlCoherent);
        t.connect(e1, leaves[1], LinkKind::CxlCoherent);
        let f = Fabric::new(t);
        let p = f.path(e0, e1).unwrap();
        assert_eq!(p.hops(), 4);
        let br = f.message_latency(&p, 1e7); // 10 MB
        let one_serialization = 1e7 / (128.0 * 0.95);
        assert!(br.serialization_ns < 1.25 * one_serialization,
            "serialization {} not pipelined (1x = {one_serialization})", br.serialization_ns);
        assert!(br.total_ns() > one_serialization);
    }

    #[test]
    fn path_bandwidth_is_bottleneck() {
        let mut t = Topology::new();
        let a = t.add_node(NodeKind::Accelerator, "a");
        let s = t.add_switch(crate::fabric::switch::SwitchParams::for_link(LinkKind::CxlCoherent), "s");
        let b = t.add_node(NodeKind::MemoryNode, "m");
        t.connect(a, s, LinkKind::CxlCoherent); // 128 GB/s
        t.connect(s, b, LinkKind::InfiniBandNdr); // 50 GB/s
        let f = Fabric::new(t);
        let p = f.path(a, b).unwrap();
        let bw = f.path_bandwidth(&p, 1e6);
        assert!(bw < 50.0, "bottleneck must be IB: {bw}");
    }
}
