//! Switch model: per-hop latency (the paper's "empirical measurements from
//! our silicon prototypes"), radix, PBR routing decision cost, and a simple
//! M/D/1 queuing adder for loaded ports (§6: "queuing behaviors at both
//! link and transaction layers").

use super::link::LinkKind;

/// Parameters of one switch class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchParams {
    /// Port count (radix).
    pub radix: usize,
    /// Fixed cut-through forwarding latency per hop, ns.
    pub hop_ns: f64,
    /// Extra per-hop cost of a PBR routing decision, ns (CXL 3.x port-based
    /// routing table lookup; zero for fixed single-hop crossbars).
    pub pbr_ns: f64,
    /// Whether this switch can cascade into multi-level fabrics (CXL 3.x
    /// switch cascading; XLink switches cannot).
    pub cascadable: bool,
}

impl SwitchParams {
    /// Default switch class for a link technology.
    pub fn for_link(kind: LinkKind) -> SwitchParams {
        match kind {
            // NVSwitch complex (9 trays in an NVL72): single-stage
            // crossbar, no routing flexibility; 72 GPU ports + uplinks
            LinkKind::NvLink5 => SwitchParams { radix: 144, hop_ns: 100.0, pbr_ns: 0.0, cascadable: false },
            // UALink switch: single-hop only per spec
            LinkKind::UaLink => SwitchParams { radix: 128, hop_ns: 150.0, pbr_ns: 0.0, cascadable: false },
            // CXL 3.x PBR switch — "empirical measurements from our silicon
            // prototypes" (paper §6); cascading + PBR enabled
            LinkKind::CxlCoherent => SwitchParams { radix: 64, hop_ns: 180.0, pbr_ns: 20.0, cascadable: true },
            LinkKind::CxlCapacity => SwitchParams { radix: 64, hop_ns: 200.0, pbr_ns: 20.0, cascadable: true },
            LinkKind::PcieGen5 => SwitchParams { radix: 32, hop_ns: 250.0, pbr_ns: 0.0, cascadable: true },
            // IB switch ASIC
            LinkKind::InfiniBandNdr => SwitchParams { radix: 64, hop_ns: 300.0, pbr_ns: 0.0, cascadable: true },
        }
    }

    /// Total traversal latency of this switch, ns.
    pub fn traversal_ns(&self) -> f64 {
        self.hop_ns + self.pbr_ns
    }

    /// M/D/1 mean queuing delay adder at utilization `rho` for a mean
    /// service time `service_ns` (per-flit). Saturates (capped) near 1.0
    /// to keep the analytic model finite; the event-driven simulator in
    /// `crate::sim` models the real queue.
    pub fn queuing_ns(&self, rho: f64, service_ns: f64) -> f64 {
        let rho = rho.clamp(0.0, 0.99);
        // M/D/1: Wq = rho / (2 (1 - rho)) * service
        rho / (2.0 * (1.0 - rho)) * service_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cxl_switches_cascade_xlink_do_not() {
        // §2: "cascading enables multiple switches to interconnect
        // hierarchically" is what distinguishes CXL from XLink
        assert!(SwitchParams::for_link(LinkKind::CxlCoherent).cascadable);
        assert!(SwitchParams::for_link(LinkKind::CxlCapacity).cascadable);
        assert!(!SwitchParams::for_link(LinkKind::NvLink5).cascadable);
        assert!(!SwitchParams::for_link(LinkKind::UaLink).cascadable);
    }

    #[test]
    fn pbr_costs_only_on_cxl() {
        assert!(SwitchParams::for_link(LinkKind::CxlCoherent).pbr_ns > 0.0);
        assert_eq!(SwitchParams::for_link(LinkKind::NvLink5).pbr_ns, 0.0);
    }

    #[test]
    fn queuing_grows_with_load() {
        let s = SwitchParams::for_link(LinkKind::CxlCoherent);
        let q1 = s.queuing_ns(0.1, 10.0);
        let q2 = s.queuing_ns(0.5, 10.0);
        let q3 = s.queuing_ns(0.9, 10.0);
        assert!(q1 < q2 && q2 < q3);
        assert_eq!(s.queuing_ns(0.0, 10.0), 0.0);
    }

    #[test]
    fn queuing_bounded_at_saturation() {
        let s = SwitchParams::for_link(LinkKind::CxlCoherent);
        assert!(s.queuing_ns(2.0, 10.0).is_finite());
    }

    #[test]
    fn nvswitch_radix_covers_rack() {
        // 72 GPUs per NVL72 rack + fabric uplinks must hang off the complex
        assert!(SwitchParams::for_link(LinkKind::NvLink5).radix > 72);
    }
}
