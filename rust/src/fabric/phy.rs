//! PHY-layer characteristics (Table 1's "PHY" row).
//!
//! Each interconnect family rides a different physical layer with different
//! encoding/FEC cost. The numbers are latency *adders* in ns, applied once
//! per link traversal in each direction; bandwidth efficiency scales the
//! raw signaling rate down to usable payload rate.

/// Physical-layer family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phy {
    /// NVLink's proprietary NRZ/PAM4 signaling: minimal latency adder.
    Proprietary,
    /// Ethernet-based (UALink): PAM4 + lightweight FEC.
    Ethernet,
    /// PCIe-based (CXL, plain PCIe): 1b/1b flit mode encoding + FEC (Gen6)
    /// or 128b/130b (Gen5).
    Pcie,
    /// InfiniBand PHY (also used for the RDMA baseline).
    InfiniBand,
}

impl Phy {
    /// One-way latency adder of the PHY (serdes + encode/decode + FEC), ns.
    pub fn latency_ns(self) -> f64 {
        match self {
            Phy::Proprietary => 15.0, // custom serdes, no FEC on short reach
            Phy::Ethernet => 60.0,    // PAM4 + RS-FEC lite
            Phy::Pcie => 25.0,        // flit-mode FEC (Gen6-class)
            Phy::InfiniBand => 50.0,
        }
    }

    /// Fraction of raw signaling bandwidth available to the link layer
    /// after encoding/FEC overhead.
    pub fn efficiency(self) -> f64 {
        match self {
            Phy::Proprietary => 0.97,
            Phy::Ethernet => 0.94,
            Phy::Pcie => 0.95,
            Phy::InfiniBand => 0.94,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phy::Proprietary => "Proprietary",
            Phy::Ethernet => "Ethernet-based",
            Phy::Pcie => "PCIe-based",
            Phy::InfiniBand => "InfiniBand",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_phys_have_positive_latency_and_sane_efficiency() {
        for p in [Phy::Proprietary, Phy::Ethernet, Phy::Pcie, Phy::InfiniBand] {
            assert!(p.latency_ns() > 0.0);
            assert!(p.efficiency() > 0.5 && p.efficiency() <= 1.0);
        }
    }

    #[test]
    fn proprietary_is_fastest_phy() {
        // Table 1: NVLink "very low" latency rests partly on its PHY
        assert!(Phy::Proprietary.latency_ns() < Phy::Ethernet.latency_ns());
        assert!(Phy::Proprietary.latency_ns() < Phy::Pcie.latency_ns());
    }
}
