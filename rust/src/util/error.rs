//! Minimal error plumbing (anyhow is not in the offline vendor set): a
//! string-backed error type, `Result` alias, `Context` extension for
//! `Result`/`Option`, and `bail!`/`ensure!`/`anyhow!` macros covering the
//! subset of the anyhow API this crate uses.

use std::fmt;

/// A boxed, context-annotated error message.
///
/// Deliberately does NOT implement [`std::error::Error`]: that keeps the
/// blanket `From<E: std::error::Error>` conversion below coherent (the
/// same trick anyhow plays), so `?` works on any std error source.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line (outermost first, like anyhow's chain).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context(self, c: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Bail unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

pub use crate::{anyhow_err, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file").context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "), "{e}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
    }

    #[test]
    fn with_context_lazily_formats() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1: inner");
    }
}
