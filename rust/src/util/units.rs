//! Unit helpers: byte sizes, times in nanoseconds, bandwidths in bytes/s.
//!
//! The entire simulator works in **f64 nanoseconds** and **f64 bytes/second**
//! — latencies in this domain span 6 orders of magnitude (sub-ns wire delay
//! to ms-scale storage), so floating point is the right currency.

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
pub const TIB: f64 = 1024.0 * GIB;

pub const KB: f64 = 1e3;
pub const MB: f64 = 1e6;
pub const GB: f64 = 1e9;
pub const TB: f64 = 1e12;

pub const US: f64 = 1_000.0; // ns
pub const MS: f64 = 1_000_000.0; // ns
pub const SEC: f64 = 1e9; // ns

/// GB/s -> bytes/ns (the simulator's bandwidth unit).
pub const fn gbps(gb_per_s: f64) -> f64 {
    gb_per_s // 1 GB/s == 1 byte/ns exactly (decimal GB)
}

/// Human-format a nanosecond duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Human-format a byte count.
pub fn fmt_bytes(b: f64) -> String {
    if b < KIB {
        format!("{b:.0} B")
    } else if b < MIB {
        format!("{:.1} KiB", b / KIB)
    } else if b < GIB {
        format!("{:.1} MiB", b / MIB)
    } else if b < TIB {
        format!("{:.2} GiB", b / GIB)
    } else {
        format!("{:.2} TiB", b / TIB)
    }
}

/// Human-format bandwidth given bytes/ns.
pub fn fmt_bw(bytes_per_ns: f64) -> String {
    format!("{:.1} GB/s", bytes_per_ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gb_per_s_is_bytes_per_ns() {
        assert_eq!(gbps(100.0), 100.0);
        // 100 GB/s * 1 ns = 100 bytes
        assert_eq!(gbps(100.0) * 1.0, 100.0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_ns(3.2e6), "3.20 ms");
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2.0 * GIB), "2.00 GiB");
    }
}
