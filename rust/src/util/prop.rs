//! Tiny property-test harness (proptest is not in the offline vendor set).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`. On failure it performs a simple halving **shrink**
//! over the generator's size parameter and reports the smallest failing
//! seed/case so the failure is reproducible.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0x5ca1_ab1e }
    }
}

/// Run `prop` against `cases` inputs drawn by `gen`. Panics with the
/// reproducing seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {case_seed:#x}); input = {input:#?}"
            );
        }
    }
}

/// As `forall` but the property returns `Result` so failures carry a reason.
pub fn forall_res<T: std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(why) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {case_seed:#x}): {why}\ninput = {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(
            Config { cases: 64, seed: 1 },
            |r| r.below(100),
            |&x| {
                n += 1;
                x < 100
            },
        );
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(Config { cases: 64, seed: 1 }, |r| r.below(100), |&x| x < 50);
    }
}
