//! Summary statistics for benches and the simulator (mean, σ, percentiles).

/// Summary of a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `samples` is consumed (sorted in place).
    pub fn from(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty(), "empty sample set");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
            p50: percentile_sorted(&samples, 0.50),
            p90: percentile_sorted(&samples, 0.90),
            p99: percentile_sorted(&samples, 0.99),
        }
    }

    /// 95% CI half-width for the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Streaming mean/variance (Welford) — used in the event-sim hot loop where
/// storing every sample would dominate memory.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::from(xs);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Summary::from(vec![]);
    }
}
