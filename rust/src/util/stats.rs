//! Summary statistics for benches and the simulator (mean, σ, percentiles).

/// Summary of a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `samples` is consumed (sorted in place).
    pub fn from(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty(), "empty sample set");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
            p50: percentile_sorted(&samples, 0.50),
            p90: percentile_sorted(&samples, 0.90),
            p99: percentile_sorted(&samples, 0.99),
        }
    }

    /// 95% CI half-width for the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Bins per decade of the [`LogHistogram`]: 32 gives ~±3.7% bin width
/// (10^(1/64) half-bin), plenty for tail-inflation ratios.
const LOG_BINS_PER_DECADE: usize = 32;
/// Smallest resolvable value, ns; everything at or below lands in bin 0.
const LOG_MIN: f64 = 0.1;
/// Covered range: 0.1 ns .. ~10^12 ns (≈ 17 minutes of simulated time).
const LOG_DECADES: usize = 13;
const LOG_NBINS: usize = LOG_DECADES * LOG_BINS_PER_DECADE;

/// Fixed-memory log-binned histogram for streaming latency percentiles:
/// the event-sim completion path cannot store every sample (the streamed
/// memory contract is O(peak in-flight), never O(workload)), so
/// percentiles come from 416 logarithmic bins at ~±4% resolution.
/// Deterministic and mergeable — identical sample streams (e.g. the
/// serial and sharded backends) produce identical histograms.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Box<[u64]>,
    n: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram { counts: vec![0u64; LOG_NBINS].into_boxed_slice(), n: 0 }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        let b = if x <= LOG_MIN {
            0
        } else {
            (((x / LOG_MIN).log10() * LOG_BINS_PER_DECADE as f64) as usize).min(LOG_NBINS - 1)
        };
        self.counts[b] += 1;
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Value at quantile `q` in `[0, 1]`: the geometric midpoint of the
    /// bin holding the rank-`q` sample (0.0 when empty).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return LOG_MIN * 10f64.powf((i as f64 + 0.5) / LOG_BINS_PER_DECADE as f64);
            }
        }
        LOG_MIN * 10f64.powf((LOG_NBINS as f64 - 0.5) / LOG_BINS_PER_DECADE as f64)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    /// Fold another histogram in (bin-exact: both share the fixed
    /// geometry).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
    }
}

/// Streaming mean/variance (Welford) — used in the event-sim hot loop where
/// storing every sample would dominate memory.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::from(xs);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Summary::from(vec![]);
    }

    #[test]
    fn log_histogram_percentiles_within_bin_resolution() {
        let mut h = LogHistogram::new();
        let mut xs = Vec::new();
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..20_000 {
            let x = 10f64.powf(rng.f64() * 6.0); // 1 ns .. 1e6 ns, log-uniform
            h.push(x);
            xs.push(x);
        }
        let s = Summary::from(xs);
        for (got, want) in [(h.p50(), s.p50), (h.p99(), s.p99)] {
            let rel = (got - want).abs() / want;
            assert!(rel < 0.05, "histogram {got} vs exact {want} ({:.1}% off)", rel * 100.0);
        }
        assert_eq!(h.count(), 20_000);
    }

    #[test]
    fn log_histogram_edge_values() {
        let mut h = LogHistogram::new();
        assert_eq!(h.p99(), 0.0, "empty histogram reports 0");
        h.push(0.0); // at-or-below-floor clamps into bin 0
        h.push(-5.0);
        h.push(1e30); // beyond the range clamps into the last bin
        assert_eq!(h.count(), 3);
        assert!(h.percentile(0.0) > 0.0);
        assert!(h.p99().is_finite());
    }

    #[test]
    fn log_histogram_merge_matches_combined_stream() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut all = LogHistogram::new();
        let mut rng = crate::util::Rng::new(9);
        for i in 0..5_000 {
            let x = 1.0 + rng.f64() * 1e5;
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.p50(), all.p50());
        assert_eq!(a.p99(), all.p99());
    }
}
