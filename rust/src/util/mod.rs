//! Self-contained utilities (the offline vendor set has no rand/serde/
//! criterion/proptest, so the pieces we need are implemented here).

pub mod rng;
pub mod stats;
pub mod json;
pub mod units;
pub mod prop;
pub mod error;
pub mod par;

pub use json::Json;
pub use par::par_map;
pub use rng::Rng;
pub use stats::Summary;
