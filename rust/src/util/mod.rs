//! Self-contained utilities (the offline vendor set has no rand/serde/
//! criterion/proptest, so the pieces we need are implemented here).

pub mod rng;
pub mod stats;
pub mod json;
pub mod units;
pub mod prop;

pub use json::Json;
pub use rng::Rng;
pub use stats::Summary;
