//! Deterministic PRNG: splitmix64 seeding + xoshiro256++ core.
//!
//! Used by workload generators, the discrete-event simulator, and the
//! property-test harness. No external crates (offline build).

/// xoshiro256++ with splitmix64 seeding. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX - n + 1) % n;
        loop {
            let v = self.next_u64();
            if v <= zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with given rate.
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Zipf-distributed rank in [0, n) with exponent `theta` (0 = uniform).
    /// Inverse-CDF by binary search over the precomputed harmonic table is
    /// overkill here; we use the approximation of Gray et al. (quick and
    /// adequate for workload skew). The zeta constants are memoized per
    /// (n, theta) — recomputing the truncated harmonic per draw dominated
    /// the workload generators before (§Perf).
    pub fn zipf(&mut self, n: u64, theta: f64) -> u64 {
        if theta <= 1e-9 {
            return self.below(n);
        }
        let (zetan, eta) = zipf_constants(n, theta);
        let alpha = 1.0 / (1.0 - theta);
        let u = self.f64();
        let uz = u * zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(theta) {
            return 1;
        }
        ((n as f64) * (eta * u - eta + 1.0).powf(alpha)) as u64 % n
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG with decorrelated state (for parallel streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// Memoized (zeta(n, theta), eta) pairs — a tiny thread-local map; the
/// workload generators only ever use a handful of distinct (n, theta).
fn zipf_constants(n: u64, theta: f64) -> (f64, f64) {
    use std::cell::RefCell;
    thread_local! {
        static CACHE: RefCell<Vec<((u64, u64), (f64, f64))>> = const { RefCell::new(Vec::new()) };
    }
    let key = (n, theta.to_bits());
    CACHE.with(|c| {
        if let Some(&(_, v)) = c.borrow().iter().find(|(k, _)| *k == key) {
            return v;
        }
        let zetan = zeta(n, theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta(2, theta) / zetan);
        let mut b = c.borrow_mut();
        if b.len() > 64 {
            b.clear(); // unbounded growth guard
        }
        b.push((key, (zetan, eta)));
        (zetan, eta)
    })
}

fn zeta(n: u64, theta: f64) -> f64 {
    // truncated harmonic; capped term count keeps workload gen O(1) amortized
    let cap = n.min(10_000);
    let mut sum = 0.0;
    for i in 1..=cap {
        sum += 1.0 / (i as f64).powf(theta);
    }
    if n > cap {
        // integral tail approximation
        sum += ((n as f64).powf(1.0 - theta) - (cap as f64).powf(1.0 - theta)) / (1.0 - theta);
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_skews_low_ranks() {
        let mut r = Rng::new(13);
        let mut lo = 0;
        for _ in 0..10_000 {
            if r.zipf(1000, 0.99) < 10 {
                lo += 1;
            }
        }
        // with theta=0.99 the first 10 ranks should draw a large share
        assert!(lo > 2_000, "zipf low-rank share {lo}");
    }

    #[test]
    fn zipf_zero_theta_uniformish() {
        let mut r = Rng::new(13);
        let mut lo = 0;
        for _ in 0..10_000 {
            if r.zipf(1000, 0.0) < 10 {
                lo += 1;
            }
        }
        assert!(lo < 300, "uniform low-rank share {lo}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
