//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar except for `\u` surrogate pairs beyond
//! the BMP. Used for the AOT artifact manifests (`artifacts/*.manifest.json`)
//! and for experiment result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", Json::str("tiny")),
            ("shape", Json::arr(vec![Json::num(2.0), Json::num(64.0)])),
            ("ok", Json::Bool(true)),
        ]);
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let s = r#"{"preset":"tiny","n_params":17,"params":["tok_embed"],
                    "train_step":{"artifact":"tiny.train_step.hlo.txt",
                    "inputs":[{"name":"tok_embed","shape":[256,64],"dtype":"f32"}]}}"#;
        let v = Json::parse(s).unwrap();
        let inp = v.get("train_step").unwrap().get("inputs").unwrap().idx(0).unwrap();
        assert_eq!(inp.get("shape").unwrap().idx(0).unwrap().as_u64(), Some(256));
        assert_eq!(inp.get("dtype").unwrap().as_str(), Some("f32"));
    }
}
