//! Scoped-thread data parallelism (rayon is not in the offline vendor
//! set): an order-preserving `par_map` over slices, used by the routing
//! table builder, the experiment sweeps and the workload generators.
//!
//! Work is split into one contiguous chunk per worker; results come back
//! in input order. Falls back to a plain serial map when there is a single
//! hardware thread or at most one item, so callers never pay spawn
//! overhead on trivial inputs.

/// Number of worker threads to use for a job of `items` independent units.
pub fn workers_for(items: usize) -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(items.max(1))
}

/// Worker count for a sharded simulation over a fabric partitioned into
/// `domains` topology domains: one shard per hardware thread, never more
/// than the domain count (a shard with no links would only add sync cost).
/// This is an upper bound handed to the partitioner — when reactive
/// sources declare footprints, the coupled-domain constraint pass
/// ([`Topology::partition_domains_coupled`](crate::fabric::Topology::partition_domains_coupled))
/// may merge domains below it to keep each footprint inside one shard.
pub fn shards_for(domains: usize) -> usize {
    workers_for(domains)
}

/// Map `f` over `items` across scoped threads, preserving order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = workers_for(n);
    if workers <= 1 || n <= 1 {
        return items.iter().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut parts: Vec<Vec<R>> = Vec::with_capacity(workers);
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| s.spawn(move || c.iter().map(f).collect::<Vec<R>>()))
            .collect();
        for h in handles {
            parts.push(h.join().expect("par_map worker panicked"));
        }
    });
    parts.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(&xs, |&x| x * x);
        assert_eq!(ys.len(), 1000);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, (i as u64) * (i as u64));
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = vec![];
        assert!(par_map(&none, |&x| x).is_empty());
        assert_eq!(par_map(&[41u32], |&x| x + 1), vec![42]);
    }

    #[test]
    fn matches_serial_map_on_shared_state() {
        // closures capture by shared reference only; results must be
        // identical to the serial map regardless of scheduling
        let base = vec![3.0f64, 1.5, 9.25, -2.0, 0.0, 7.125];
        let scale = 2.5f64;
        let par = par_map(&base, |&x| x * scale);
        let ser: Vec<f64> = base.iter().map(|&x| x * scale).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn workers_bounded_by_items() {
        assert_eq!(workers_for(0), 1);
        assert!(workers_for(1) <= 1);
        assert!(workers_for(1_000_000) >= 1);
    }
}
