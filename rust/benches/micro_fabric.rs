//! Micro-benchmarks of the simulator's hot paths (the §Perf targets):
//! routing-table construction, path latency evaluation, the discrete-event
//! engine, the MESI directory, the pool allocator and workload generation.
//!
//! Run with: `cargo bench --bench micro_fabric`

use scalepool::bench::{black_box, BenchConfig, BenchGroup};
use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
use scalepool::coherence::Directory;
use scalepool::fabric::{LinkKind, NodeKind, Topology, TopologyKind};
use scalepool::memory::pool::{MemoryPool, Placement};
use scalepool::memory::Tier;
use scalepool::sim::{Engine, EventKind, MemSim, Transaction};
use scalepool::util::Rng;
use scalepool::workloads::WorkingSetSweep;

fn main() {
    let mut g = BenchGroup::new("fabric").with_config(BenchConfig { warmup_iters: 3, iters: 30 });

    let sys = ScalePoolBuilder::new()
        .racks((0..8).map(|i| Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 16).unwrap()))
        .config(SystemConfig { inter: InterCluster::Cxl(TopologyKind::MultiLevelClos), mem_nodes: 8, ..Default::default() })
        .build();
    println!(
        "system under test: {} nodes, {} links",
        sys.fabric.topo.nodes.len(),
        sys.fabric.topo.links.len()
    );

    g.bench("build 8x16 system (topology + routing)", || {
        ScalePoolBuilder::new()
            .racks((0..8).map(|i| Rack::homogeneous(&format!("r{i}"), Accelerator::b200(), 16).unwrap()))
            .config(SystemConfig { inter: InterCluster::Cxl(TopologyKind::MultiLevelClos), mem_nodes: 8, ..Default::default() })
            .build()
    });

    let src = sys.racks[0].acc_ids[0];
    let dst = sys.racks[7].acc_ids[15];
    g.bench("path + message_latency (cross-fabric)", || {
        let p = sys.fabric.path(src, dst).unwrap();
        sys.fabric.message_latency(&p, 65536.0).total_ns()
    });

    g.bench("torus3d(8,8,8) build + route", || {
        let (t, ids) = Topology::torus3d((8, 8, 8), LinkKind::CxlCoherent, "t");
        let f = scalepool::fabric::Fabric::new(t);
        f.latency_ns(ids[0], ids[ids.len() - 1], 4096.0)
    });

    // --- event engine -----------------------------------------------------
    let mut g = BenchGroup::new("event engine").with_config(BenchConfig { warmup_iters: 2, iters: 10 });
    g.bench("schedule+dispatch 1M events", || {
        let mut e = Engine::new();
        let mut rng = Rng::new(1);
        for _ in 0..100_000 {
            e.schedule(rng.f64() * 1e6, EventKind::Custom { tag: 0 });
        }
        let mut n = 0u64;
        while e.next().is_some() {
            n += 1;
            if n % 10 == 0 {
                // keep the heap warm like a real simulation
                let now = e.now();
                e.schedule(now + 100.0, EventKind::Custom { tag: 1 });
                n += 1;
                if n > 1_000_000 {
                    break;
                }
            }
        }
        n
    });

    let rack = Topology::single_hop(16, LinkKind::NvLink5, "r");
    let accs = rack.nodes_of(NodeKind::Accelerator);
    let fabric = scalepool::fabric::Fabric::new(rack);
    g.bench("memsim 100k transactions (16-acc rack)", || {
        let mut rng = Rng::new(2);
        let mut at = 0.0;
        let txs: Vec<Transaction> = (0..100_000)
            .map(|_| {
                at += rng.exp(1.0 / 20.0);
                let s = accs[rng.below(16) as usize];
                let mut d = accs[rng.below(16) as usize];
                while d == s {
                    d = accs[rng.below(16) as usize];
                }
                Transaction { src: s, dst: d, at, bytes: 4096.0, device_ns: 100.0 }
            })
            .collect();
        let mut sim = MemSim::new(&fabric);
        sim.run(txs).completed
    });

    // --- coherence directory ------------------------------------------------
    let mut g = BenchGroup::new("coherence").with_config(BenchConfig { warmup_iters: 3, iters: 20 });
    g.bench("MESI directory 100k mixed ops (8 agents)", || {
        let mut d = Directory::new(8);
        let mut rng = Rng::new(3);
        let mut msgs = 0u64;
        for _ in 0..100_000 {
            let a = rng.below(8) as usize;
            let b = rng.below(4096);
            msgs += if rng.f64() < 0.3 { d.write(a, b) } else { d.read(a, b) }.total() as u64;
        }
        msgs
    });

    // --- pool allocator -------------------------------------------------------
    let mut g = BenchGroup::new("memory pool").with_config(BenchConfig { warmup_iters: 3, iters: 20 });
    g.bench("alloc/free churn 10k ops (3 regions)", || {
        let mut p = MemoryPool::new();
        p.add_region(0, Tier::Tier1Local, 1e12);
        p.add_region(1, Tier::Tier1Remote, 1e13);
        p.add_region(2, Tier::Tier2Pool, 1e14);
        let mut rng = Rng::new(4);
        let mut live = Vec::new();
        for _ in 0..10_000 {
            if rng.f64() < 0.6 || live.is_empty() {
                if let Ok(a) = p.alloc(rng.f64_range(1e6, 1e9), Placement::FirstFit) {
                    live.push(a.id);
                }
            } else {
                let i = rng.below(live.len() as u64) as usize;
                let id = live.swap_remove(i);
                p.free(id).unwrap();
            }
        }
        black_box(p.used())
    });

    // --- workload generation -----------------------------------------------
    let mut g = BenchGroup::new("workloads").with_config(BenchConfig { warmup_iters: 2, iters: 10 });
    g.bench("working-set trace 100k accesses", || {
        WorkingSetSweep { accesses: 100_000, ..Default::default() }.trace(1e12).accesses.len()
    });
    g.bench("zipf draw x 100k (n=1e9)", || {
        let mut rng = Rng::new(5);
        let mut acc = 0u64;
        for _ in 0..100_000 {
            acc = acc.wrapping_add(rng.zipf(1_000_000_000, 0.9));
        }
        acc
    });
}
