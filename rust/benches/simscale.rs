//! Bench SIMSCALE — the perf trajectory of the simulation hot path at
//! rack (72), row (~1k) and pod (~4k) endpoint counts:
//!
//! * `Router::build` (flat parallel PBR table) vs the seed serial
//!   nested-table BFS (`fabric::routing::reference::SerialRouter`), plus
//!   the K=4 multipath build (`Router::build_multipath`), asserted to
//!   stay within 2x of the single-path build so the multi-rail table
//!   cannot silently regress the PR-1 router-build bar;
//! * sustained `MemSim` events/sec (calendar engine + interned paths +
//!   precomputed direction bits) vs a faithful replica of the seed loop
//!   (payload-carrying heap events, one `Vec` path clone per transaction,
//!   per-event link-endpoint direction derivation);
//! * sharded multi-core streamed simulation (`run_streamed_sharded`) vs
//!   the serial streamed backend, on scales whose topology yields more
//!   than one domain (the single-crossbar rack does not shard);
//! * reactive sharding (ISSUE 7): closed-loop per-leaf coherence domains
//!   plus per-leaf collective rings, serial vs sharded with every source
//!   pinned to the shard owning its footprint — asserted to actually
//!   shard (no serial fallback) and, at pod scale on >= 4 cores, to beat
//!   the serial backend by >= 1.5x;
//! * optimistic sharding (ISSUE 8): the same per-leaf coherence domains
//!   plus ONE collective ring spanning every endpoint — a footprint no
//!   partition can contain, which pre-PR-8 forced the serial fallback.
//!   The sharded backend checkpoints at epoch barriers, speculates the
//!   ring's injections and rolls back on divergence; asserted to shard
//!   with exactly one optimistic source, to checkpoint, and at pod scale
//!   on >= 4 cores to beat the serial backend by >= 1.3x;
//! * flight-recorder overhead (ISSUE 9): the same memsim workload with
//!   the bounded trace ring armed — `trace_overhead_ratio` is advisory;
//!   the gated bar stays the untraced events/sec, because the disabled
//!   recorder is one `Option` check per event arm;
//! * express dispatch (ISSUE 10): a *sparse* open-loop workload
//!   (interarrivals far above the per-hop latency — the regime where
//!   nearly every hop beats the peek gate) run fused vs
//!   `set_fusion(false)` on the serial streamed backend. Both runs
//!   process the identical logical event count (fusion is byte-inert;
//!   a fused hop counts as the event it replaced), so `fused_speedup`
//!   is a pure wall-time ratio. `SCALEPOOL_BENCH_FUSION=off` disables
//!   fusion on every run and skips this section;
//! * sweep-point throughput: copy-on-write forking (`MemSim::fork` off a
//!   warmed, frozen master) vs rebuilding the fabric + simulator for
//!   every point — the sweep-harness pattern the experiments use;
//! * raw engine schedule/dispatch throughput, calendar vs seed-style heap.
//!
//! Writes machine-readable results to `BENCH_simscale.json` (override the
//! path with `SCALEPOOL_BENCH_OUT`; bound the run with
//! `SCALEPOOL_BENCH_SCALES=rack,row` and `SCALEPOOL_BENCH_ACCESSES=N` —
//! the CI smoke uses both). Acceptance bars: >= 5x router build and
//! >= 3x events/sec at pod scale (ISSUE 1); sharded >= 2x the serial
//! streamed backend at pod scale on >= 4 cores (ISSUE 3); forked sweep
//! points >= 3x rebuild-per-point at row scale and beyond (ISSUE 6);
//! optimistic sharded >= 1.3x serial at pod scale on >= 4 cores
//! (ISSUE 8); fused >= 1.5x unfused with a fusion rate >= 0.5 on the
//! sparse workload at pod scale (ISSUE 10).
//!
//! Run with: `cargo bench --bench simscale` (see `scripts/bench.sh`).

use scalepool::bench::black_box;
use scalepool::coherence::{CoherenceConfig, CoherenceTraffic};
use scalepool::collective::EventDrivenCollective;
use scalepool::fabric::routing::reference::SerialRouter;
use scalepool::fabric::{Fabric, LinkKind, NodeKind, Router, Topology};
use scalepool::sim::{BatchSource, Engine, EventKind, MemSim, Server, TraceConfig, TrafficClass, TrafficSource, Transaction};
use scalepool::util::Json;
use scalepool::workloads::{AccessTrace, WorkingSetSweep};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

// ---------------------------------------------------------------------------
// seed replicas (the pre-overhaul implementations, measured as baselines)
// ---------------------------------------------------------------------------

/// Seed event heap: full payload-carrying events moved through every sift.
#[derive(Clone, Debug)]
struct SeedEvent {
    at: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for SeedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for SeedEvent {}
impl PartialOrd for SeedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for SeedEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Default)]
struct SeedEngine {
    heap: BinaryHeap<SeedEvent>,
    now: f64,
    seq: u64,
    dispatched: u64,
}

impl SeedEngine {
    fn schedule(&mut self, at: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(SeedEvent { at, seq: self.seq, kind });
    }
    fn after(&mut self, delay: f64, kind: EventKind) {
        let at = self.now + delay;
        self.schedule(at, kind);
    }
    fn next(&mut self) -> Option<(f64, EventKind)> {
        let ev = self.heap.pop()?;
        self.now = ev.at;
        self.dispatched += 1;
        Some((ev.at, ev.kind))
    }
}

struct SeedInFlight {
    src: usize,
    issued: f64,
    bytes: f64,
    device_ns: f64,
    path_links: Vec<usize>,
}

#[derive(Clone, Copy)]
struct SeedLinkConsts {
    inv_rate: f64,
    fixed_ns: f64,
    switch_ns: [f64; 2],
}

/// The seed `MemSim::run` loop, verbatim in structure: nested-table
/// routing, a cloned `path_links` vector per transaction, and the hop
/// direction re-derived from link endpoints on every Arrive event.
fn seed_sim_run(fabric: &Fabric, router: &SerialRouter, txs: &[Transaction]) -> (u64, u64) {
    let topo = &fabric.topo;
    let mut servers: Vec<[Server; 2]> =
        (0..topo.links.len()).map(|_| [Server::new(), Server::new()]).collect();
    let consts: Vec<SeedLinkConsts> = topo
        .links
        .iter()
        .map(|l| {
            let p = &l.params;
            let sw =
                |n: usize| topo.node(n).switch.as_ref().map(|s| s.traversal_ns()).unwrap_or(0.0);
            SeedLinkConsts {
                inv_rate: 1.0 / (p.raw_bw * p.phy.efficiency()),
                fixed_ns: p.prop_ns + p.phy.latency_ns() + p.flit_overhead_ns,
                switch_ns: [sw(l.a), sw(l.b)],
            }
        })
        .collect();

    let mut engine = SeedEngine::default();
    let mut inflight: Vec<Option<SeedInFlight>> = Vec::with_capacity(txs.len());
    let mut links = Vec::new();
    for tx in txs {
        if !router.links_into(tx.src, tx.dst, &mut links) && tx.src != tx.dst {
            panic!("no path {} -> {}", tx.src, tx.dst);
        }
        let id = inflight.len();
        engine.schedule(tx.at, EventKind::Arrive { id, hop: 0 });
        inflight.push(Some(SeedInFlight {
            src: tx.src,
            issued: tx.at,
            bytes: tx.bytes,
            device_ns: tx.device_ns,
            path_links: links.clone(),
        }));
    }

    let mut completed = 0u64;
    let mut latency_acc = 0.0f64;
    while let Some((now, ev)) = engine.next() {
        match ev {
            EventKind::Arrive { id, hop } => {
                let fl = inflight[id].as_ref().unwrap();
                if hop >= fl.path_links.len() {
                    let dev = fl.device_ns;
                    engine.after(dev, EventKind::Complete { id });
                    continue;
                }
                let link_idx = fl.path_links[hop];
                let link = topo.link(link_idx);
                let c = &consts[link_idx];
                let from = if hop == 0 {
                    fl.src
                } else {
                    let prev = topo.link(fl.path_links[hop - 1]);
                    if prev.a == link.a || prev.b == link.a {
                        link.a
                    } else {
                        link.b
                    }
                };
                let dir = if from == link.a { 0 } else { 1 };
                let service = link.params.flit.wire_bytes(fl.bytes) * c.inv_rate;
                let done = servers[link_idx][dir].admit(now, service);
                let sw = c.switch_ns[1 - dir];
                engine.schedule(done + c.fixed_ns + sw, EventKind::Arrive { id, hop: hop + 1 });
            }
            EventKind::Complete { id } => {
                let fl = inflight[id].take().unwrap();
                latency_acc += now - fl.issued;
                completed += 1;
            }
            _ => {}
        }
    }
    black_box(latency_acc);
    (completed, engine.dispatched)
}

// ---------------------------------------------------------------------------
// harness
// ---------------------------------------------------------------------------

struct ScaleSpec {
    name: &'static str,
    leaves: usize,
    spines: usize,
    eps_per_leaf: usize,
}

/// Build the scale's topology and return (fabric-less topology, endpoint ids).
fn build_topology(s: &ScaleSpec) -> (Topology, Vec<usize>) {
    if s.leaves == 0 {
        // rack: 72 endpoints through one crossbar
        let t = Topology::single_hop(72, LinkKind::NvLink5, "rack");
        let eps = t.nodes_of(NodeKind::Accelerator);
        return (t, eps);
    }
    let (mut t, leaf_ids) = Topology::clos(s.leaves, s.spines, LinkKind::CxlCoherent, s.name);
    let mut eps = Vec::with_capacity(s.leaves * s.eps_per_leaf);
    for (i, &l) in leaf_ids.iter().enumerate() {
        for e in 0..s.eps_per_leaf {
            let n = t.add_node(NodeKind::Accelerator, format!("{}/ep{i}-{e}", s.name));
            t.connect(n, l, LinkKind::CxlCoherent);
            eps.push(n);
        }
    }
    (t, eps)
}

/// Map a working-set access trace onto endpoint-to-endpoint transactions.
fn txs_from_trace(trace: &AccessTrace, eps: &[usize], bytes: f64) -> Vec<Transaction> {
    let n = eps.len() as u64;
    trace
        .accesses
        .iter()
        .map(|a| {
            let line = a.offset / 64;
            let s = (line % n) as usize;
            let mut d = ((line / n) % n) as usize;
            if d == s {
                d = (d + 1) % eps.len();
            }
            Transaction { src: eps[s], dst: eps[d], at: a.at, bytes, device_ns: 130.0 }
        })
        .collect()
}

/// Best-of-k wall time of `f`, in ns.
fn best_of<T>(k: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..k {
        let t0 = Instant::now();
        black_box(f());
        best = best.min(t0.elapsed().as_nanos() as f64);
    }
    best
}

fn main() {
    let all_scales = [
        ScaleSpec { name: "rack", leaves: 0, spines: 0, eps_per_leaf: 0 },
        ScaleSpec { name: "row", leaves: 16, spines: 4, eps_per_leaf: 64 },
        ScaleSpec { name: "pod", leaves: 64, spines: 8, eps_per_leaf: 64 },
    ];
    // bounded runs (CI smoke): SCALEPOOL_BENCH_SCALES=rack limits the
    // sweep, SCALEPOOL_BENCH_ACCESSES shrinks the workload
    let scale_filter = std::env::var("SCALEPOOL_BENCH_SCALES").ok();
    let scales: Vec<&ScaleSpec> = all_scales
        .iter()
        .filter(|s| {
            scale_filter
                .as_deref()
                .map(|f| f.split(',').any(|n| n.trim() == s.name))
                .unwrap_or(true)
        })
        .collect();
    assert!(!scales.is_empty(), "SCALEPOOL_BENCH_SCALES matched no scale");
    let accesses: usize = std::env::var("SCALEPOOL_BENCH_ACCESSES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000);
    let tx_bytes = 4096.0;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    // SCALEPOOL_BENCH_FUSION=off: run every simulation with express
    // dispatch disabled and skip the fused A/B section entirely — the
    // escape hatch for isolating fusion from the other perf numbers
    let fuse_on = std::env::var("SCALEPOOL_BENCH_FUSION").map(|v| v != "off").unwrap_or(true);

    // trace generation for all scales at once (exercises the parallel
    // WorkingSetSweep::traces path); 2 ns mean interarrival puts the run
    // in the heavy-traffic regime the sharded backend is built for (the
    // conservative windows amortize their barrier over event density)
    let sweep = WorkingSetSweep { accesses, interval_ns: 2.0, ..Default::default() };
    let working_sets: Vec<f64> = scales.iter().map(|_| 1e12).collect();
    let traces = sweep.traces(&working_sets);

    // every simulator the bench builds honors the fusion knob, so the
    // escape hatch really does measure the unfused world end to end
    let new_sim = |fabric: &Fabric| {
        let mut sim = MemSim::new(fabric);
        if !fuse_on {
            sim.set_fusion(false);
        }
        sim
    };

    let mut rows: Vec<Json> = Vec::new();
    println!("=== simscale: router build + sustained events/sec ===");
    for (&s, trace) in scales.iter().zip(&traces) {
        let (topo, eps) = build_topology(s);
        let n_nodes = topo.nodes.len();
        let iters = if n_nodes > 2000 {
            3
        } else if n_nodes > 500 {
            5
        } else {
            20
        };

        // --- router build: flat parallel vs seed serial nested ----------
        let build_new = best_of(iters, || Router::build(&topo));
        let build_seed = best_of(iters, || SerialRouter::build(&topo));
        let build_speedup = build_seed / build_new;

        // --- multipath router build (K=4) -------------------------------
        // bar: widening every cell to 4 equal-cost rails must stay within
        // 2x of the single-path build (the 4x table memset is the only
        // extra linear cost; the BFS itself is shared). The 1 ms absolute
        // guard absorbs timer noise at rack scale, where both builds are
        // sub-millisecond and a 2x ratio would be measuring jitter.
        let build_multi = best_of(iters, || Router::build_multipath(&topo, 4));
        let build_multi_ratio = build_multi / build_new;
        assert!(
            build_multi <= 2.0 * build_new + 1e6,
            "{}: multipath (K=4) router build {:.2} ms vs single-path {:.2} ms exceeds the 2x bar",
            s.name,
            build_multi / 1e6,
            build_new / 1e6
        );

        // --- memsim throughput ------------------------------------------
        let fabric = Fabric::new(topo.clone());
        let seed_router = SerialRouter::build(&topo);
        let txs = txs_from_trace(trace, &eps, tx_bytes);
        let cross_hops = fabric.hops(eps[0], eps[eps.len() - 1]).unwrap();

        // clone the transaction stream outside the timed region (the seed
        // path borrows it, so the new path must not pay a clone in-window)
        let mut tx_pool: Vec<Vec<Transaction>> = (0..3).map(|_| txs.clone()).collect();
        let mut new_events = 0u64;
        let sim_new = best_of(3, || {
            let mut sim = new_sim(&fabric);
            let rep = sim.run(tx_pool.pop().expect("one pre-cloned stream per iteration"));
            assert_eq!(rep.completed, txs.len() as u64);
            // the streamed adapter dispatches one injection event per
            // transaction that the seed loop does not have; exclude them
            // so events/sec compares the same event mix (Arrive+Complete)
            // while the wall time still pays the injection overhead
            new_events = rep.events - rep.completed;
            rep.events
        });
        let mut seed_events = 0u64;
        let sim_seed = best_of(3, || {
            let (completed, events) = seed_sim_run(&fabric, &seed_router, &txs);
            assert_eq!(completed, txs.len() as u64);
            seed_events = events;
            events
        });
        let eps_new = new_events as f64 / (sim_new / 1e9);
        let eps_seed = seed_events as f64 / (sim_seed / 1e9);
        let sim_speedup = eps_new / eps_seed;

        // --- flight-recorder overhead (ISSUE 9) -------------------------
        // the same workload with the trace ring armed. The ratio is
        // advisory (how much the bounded per-event recording costs when
        // you ask for it); the gated number stays the untraced
        // memsim_events_per_sec above — the disabled path is one Option
        // check per event arm and must not move the baseline
        let mut traced_pool: Vec<Vec<Transaction>> = (0..3).map(|_| txs.clone()).collect();
        let mut traced_events = 0u64;
        let sim_traced = best_of(3, || {
            let mut sim = new_sim(&fabric);
            sim.set_trace(TraceConfig::default());
            let rep = sim.run(traced_pool.pop().expect("one pre-cloned stream per iteration"));
            assert_eq!(rep.completed, txs.len() as u64);
            traced_events = rep.events - rep.completed;
            rep.events
        });
        let eps_traced = traced_events as f64 / (sim_traced / 1e9);
        let trace_overhead_ratio = eps_traced / eps_new;

        // --- sharded streamed backend (ISSUE 3) -------------------------
        // only meaningful where the topology yields >1 domain and there
        // is more than one core; the single-crossbar rack is one domain
        let domains = {
            let d = fabric.topo.partition_domains(threads);
            d.iter().copied().max().map(|m| m as usize + 1).unwrap_or(1)
        };
        let sharded = if threads >= 2 && domains >= 2 {
            let shards = threads.min(domains);
            let mut pool: Vec<Vec<Transaction>> = (0..3).map(|_| txs.clone()).collect();
            let mut sharded_events = 0u64;
            let wall = best_of(3, || {
                let mut sim = new_sim(&fabric);
                let mut src = BatchSource::new(
                    pool.pop().expect("one pre-cloned stream per iteration"),
                    TrafficClass::Generic,
                );
                let rep = {
                    let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
                    sim.run_streamed_sharded_with(&mut sources, shards)
                };
                assert_eq!(rep.total.completed, txs.len() as u64);
                // same event-mix normalization as the serial number: one
                // injection-equivalent event per transaction excluded
                sharded_events = rep.total.events - rep.total.completed;
                rep.total.events
            });
            let eps_sharded = sharded_events as f64 / (wall / 1e9);
            Some((shards, eps_sharded, eps_sharded / eps_new))
        } else {
            None
        };

        // --- reactive sharding: coupled-domain pinned sources (ISSUE 7) -
        // closed-loop traffic — per-leaf coherence sharing domains and
        // per-leaf collective rings — that the pre-PR-7 backend could not
        // shard at all (reactive sources forced the serial fallback).
        // Every source declares a leaf-local footprint, so the coupled
        // plan pins each to the shard owning its leaf and the whole run
        // executes as one decoupled epoch
        let reactive = if s.leaves >= 2 && threads >= 2 {
            let groups: Vec<Vec<usize>> =
                eps.chunks(s.eps_per_leaf).map(|c| c.to_vec()).collect();
            let coh_ops = ((accesses / groups.len()) as u64 / 8).max(100);
            let ring_bytes = 1024.0 * 1024.0;
            let build_sources = || -> (Vec<CoherenceTraffic>, Vec<EventDrivenCollective>) {
                let coh = groups
                    .iter()
                    .enumerate()
                    .map(|(g, leaf)| {
                        let ccfg = CoherenceConfig {
                            ops: coh_ops,
                            mean_interarrival_ns: 25.0,
                            window: 16,
                            ..Default::default()
                        };
                        CoherenceTraffic::new(
                            leaf[1..].to_vec(),
                            vec![leaf[0]],
                            ccfg,
                            0x5EED + g as u64,
                        )
                    })
                    .collect();
                let col = groups
                    .iter()
                    .map(|leaf| EventDrivenCollective::ring(leaf.clone(), ring_bytes, 1))
                    .collect();
                (coh, col)
            };
            let run = |sharded: bool, coh: &mut Vec<CoherenceTraffic>, col: &mut Vec<EventDrivenCollective>| {
                let mut sources: Vec<&mut dyn TrafficSource> = Vec::new();
                for c in coh.iter_mut() {
                    sources.push(c);
                }
                for c in col.iter_mut() {
                    sources.push(c);
                }
                let mut sim = new_sim(&fabric);
                if sharded {
                    sim.run_streamed_sharded_with(&mut sources, threads)
                } else {
                    sim.run_streamed(&mut sources)
                }
            };
            let mut pool: Vec<_> = (0..6).map(|_| build_sources()).collect();
            let mut serial_events = 0u64;
            let serial_wall = best_of(3, || {
                let (mut coh, mut col) = pool.pop().expect("prebuilt source set");
                let rep = run(false, &mut coh, &mut col);
                serial_events = rep.total.events;
                rep.total.completed
            });
            let mut sharded_events = 0u64;
            let mut mode = scalepool::sim::ShardMode::Serial;
            let sharded_wall = best_of(3, || {
                let (mut coh, mut col) = pool.pop().expect("prebuilt source set");
                let rep = run(true, &mut coh, &mut col);
                sharded_events = rep.total.events;
                mode = rep.mode.clone();
                rep.total.completed
            });
            assert_eq!(
                serial_events, sharded_events,
                "{}: reactive backends dispatched different event counts",
                s.name
            );
            assert!(
                mode.is_sharded(),
                "{}: per-leaf reactive footprints must shard, got {mode:?}",
                s.name
            );
            let shards = match mode {
                scalepool::sim::ShardMode::Sharded { shards, .. } => shards,
                _ => unreachable!(),
            };
            let eps_serial = serial_events as f64 / (serial_wall / 1e9);
            let eps_sharded = sharded_events as f64 / (sharded_wall / 1e9);
            let speedup = eps_sharded / eps_serial;
            // the PR-7 acceptance bar: pod-scale reactive traffic 1.5x+
            // on 4+ cores (below that the barrier overhead has too few
            // workers to amortize across — check_bench treats it as
            // advisory there)
            if s.name == "pod" && threads >= 4 {
                assert!(
                    speedup >= 1.5,
                    "pod: reactive sharded speedup {speedup:.2}x below the 1.5x bar on {threads} threads"
                );
            }
            Some((shards, eps_serial, eps_sharded, speedup))
        } else {
            None
        };

        // --- optimistic sharding: footprint-spanning ring (ISSUE 8) -----
        // per-leaf coherence domains again, but this time the collective
        // is ONE ring over EVERY endpoint: its footprint spans any
        // partition, which before PR 8 forced the whole run into the
        // serial fallback. The optimistic backend checkpoints per-shard
        // state at each epoch barrier, speculates the ring's injections
        // and rolls back + replays on divergence — the bulk of the work
        // (the leaf-local coherence) still runs decoupled, so the
        // speedup survives the checkpoint/replay overhead
        let optimistic = if s.leaves >= 2 && threads >= 2 {
            let groups: Vec<Vec<usize>> =
                eps.chunks(s.eps_per_leaf).map(|c| c.to_vec()).collect();
            let coh_ops = ((accesses / groups.len()) as u64 / 8).max(100);
            let ring_bytes = 1024.0 * 1024.0;
            let build_sources = || -> (Vec<CoherenceTraffic>, EventDrivenCollective) {
                let coh = groups
                    .iter()
                    .enumerate()
                    .map(|(g, leaf)| {
                        let ccfg = CoherenceConfig {
                            ops: coh_ops,
                            mean_interarrival_ns: 25.0,
                            window: 16,
                            ..Default::default()
                        };
                        CoherenceTraffic::new(
                            leaf[1..].to_vec(),
                            vec![leaf[0]],
                            ccfg,
                            0x0B71 + g as u64,
                        )
                    })
                    .collect();
                let ring = EventDrivenCollective::ring(eps.clone(), ring_bytes, 2);
                (coh, ring)
            };
            let run = |sharded: bool,
                       coh: &mut Vec<CoherenceTraffic>,
                       ring: &mut EventDrivenCollective| {
                let mut sources: Vec<&mut dyn TrafficSource> = Vec::new();
                for c in coh.iter_mut() {
                    sources.push(c);
                }
                sources.push(ring);
                let mut sim = new_sim(&fabric);
                if sharded {
                    sim.run_streamed_sharded_with(&mut sources, threads)
                } else {
                    sim.run_streamed(&mut sources)
                }
            };
            let mut pool: Vec<_> = (0..6).map(|_| build_sources()).collect();
            let mut serial_events = 0u64;
            let serial_wall = best_of(3, || {
                let (mut coh, mut ring) = pool.pop().expect("prebuilt source set");
                let rep = run(false, &mut coh, &mut ring);
                serial_events = rep.total.events;
                rep.total.completed
            });
            let mut sharded_events = 0u64;
            let mut mode = scalepool::sim::ShardMode::Serial;
            let mut spanning = 0usize;
            let mut checkpoints = 0u64;
            let mut rollbacks = 0u64;
            let sharded_wall = best_of(3, || {
                let (mut coh, mut ring) = pool.pop().expect("prebuilt source set");
                let rep = run(true, &mut coh, &mut ring);
                sharded_events = rep.total.events;
                mode = rep.mode.clone();
                spanning = rep.optimistic_sources;
                checkpoints = rep.checkpoints;
                rollbacks = rep.rollbacks;
                rep.total.completed
            });
            assert_eq!(
                serial_events, sharded_events,
                "{}: optimistic backends dispatched different event counts",
                s.name
            );
            assert!(
                mode.is_sharded(),
                "{}: a spanning ring over checkpointable sources must shard, got {mode:?}",
                s.name
            );
            assert_eq!(spanning, 1, "{}: the global ring must run optimistically", s.name);
            assert!(checkpoints > 0, "{}: spanning epochs must checkpoint", s.name);
            let shards = match mode {
                scalepool::sim::ShardMode::Sharded { shards, .. } => shards,
                _ => unreachable!(),
            };
            let eps_serial = serial_events as f64 / (serial_wall / 1e9);
            let eps_sharded = sharded_events as f64 / (sharded_wall / 1e9);
            let speedup = eps_sharded / eps_serial;
            // the PR-8 acceptance bar: 1.3x+ at pod scale on 4+ cores —
            // lower than the fully-pinned reactive bar because every
            // gated epoch pays a checkpoint and any rollback replays the
            // whole epoch (check_bench treats sub-4-core runs as
            // advisory)
            if s.name == "pod" && threads >= 4 {
                assert!(
                    speedup >= 1.3,
                    "pod: optimistic sharded speedup {speedup:.2}x below the 1.3x bar on {threads} threads"
                );
            }
            Some((shards, eps_serial, eps_sharded, speedup, checkpoints, rollbacks))
        } else {
            None
        };

        // --- express dispatch: peek-gated hop fusion (ISSUE 10) ---------
        // the fusion regime is *sparse* traffic: when the next-hop
        // arrival beats every pending event, the whole path collapses
        // into one express chain off the first arrival. The dense 2 ns
        // workload above rarely clears the gate (its events interleave
        // by design), so this section drives its own open-loop stream
        // with interarrivals far above the per-hop latency and A/Bs the
        // serial streamed backend fused vs set_fusion(false). Both runs
        // process the identical logical event count (a fused hop counts
        // as the event it replaced — asserted), so the speedup is a
        // pure wall-time ratio
        let fused = if fuse_on {
            let sparse_n = (accesses / 10).max(2_000);
            let mut at = 0.0;
            let sparse_txs: Vec<Transaction> = (0..sparse_n)
                .map(|i| {
                    at += 2_000.0; // 2 us spacing: far above any hop latency
                    let s = (i * 7919) % eps.len();
                    let mut d = (i * 104_729 + 1) % eps.len();
                    if d == s {
                        d = (d + 1) % eps.len();
                    }
                    Transaction { src: eps[s], dst: eps[d], at, bytes: tx_bytes, device_ns: 130.0 }
                })
                .collect();
            let run_sparse = |fuse: bool, events: &mut u64, hops: &mut u64, rate: &mut f64| {
                let mut pool: Vec<Vec<Transaction>> = (0..3).map(|_| sparse_txs.clone()).collect();
                best_of(3, || {
                    let mut sim = new_sim(&fabric);
                    sim.set_fusion(fuse);
                    let mut src = BatchSource::new(
                        pool.pop().expect("one pre-cloned stream per iteration"),
                        TrafficClass::Generic,
                    );
                    let rep = {
                        let mut sources: [&mut dyn TrafficSource; 1] = [&mut src];
                        sim.run_streamed(&mut sources)
                    };
                    assert_eq!(rep.total.completed, sparse_n as u64);
                    *events = rep.total.events;
                    *hops = rep.fused_hops;
                    *rate = rep.fusion_rate();
                    rep.total.events
                })
            };
            let (mut ev_on, mut hops_on, mut rate_on) = (0u64, 0u64, 0.0f64);
            let wall_on = run_sparse(true, &mut ev_on, &mut hops_on, &mut rate_on);
            let (mut ev_off, mut hops_off, mut rate_off) = (0u64, 0u64, 0.0f64);
            let wall_off = run_sparse(false, &mut ev_off, &mut hops_off, &mut rate_off);
            assert_eq!(
                ev_on, ev_off,
                "{}: fused and unfused runs disagree on the logical event count",
                s.name
            );
            assert_eq!(hops_off, 0, "{}: set_fusion(false) still fused hops", s.name);
            assert!(hops_on > 0, "{}: sparse workload fused nothing", s.name);
            let eps_fused = ev_on as f64 / (wall_on / 1e9);
            let eps_unfused = ev_off as f64 / (wall_off / 1e9);
            let fused_speedup = eps_fused / eps_unfused;
            // the PR-10 acceptance bars: on the sparse workload at pod
            // scale, express chains must swallow at least half the
            // hop-level events and buy >= 1.5x wall time. Rack's 2-hop
            // paths leave one fusible hop per transaction, so its
            // speedup margin is thin — check_bench treats it as advisory
            // there, enforced at row and pod
            if s.name == "pod" {
                assert!(
                    rate_on >= 0.5,
                    "pod: fusion rate {rate_on:.2} below the 0.5 bar on the sparse workload"
                );
                assert!(
                    fused_speedup >= 1.5,
                    "pod: fused speedup {fused_speedup:.2}x below the 1.5x bar on the sparse workload"
                );
            }
            Some((eps_fused, eps_unfused, fused_speedup, hops_on, rate_on))
        } else {
            None
        };

        // --- sweep harness: copy-on-write fork vs rebuild (ISSUE 6) -----
        // marginal per-point throughput: the rebuild path pays a fresh
        // topology clone + Fabric (router build) + MemSim per point; the
        // forked path builds + warms + freezes a master outside the timed
        // window (the one-time setup every sweep amortizes) and pays only
        // fork + run per point
        let sweep_points = 8usize;
        let point_txs: Vec<Transaction> =
            txs.iter().take(1_000.min(txs.len())).cloned().collect();
        let mut rebuild_pool: Vec<Vec<Transaction>> =
            (0..sweep_points).map(|_| point_txs.clone()).collect();
        let rebuild_wall = {
            let t0 = Instant::now();
            for _ in 0..sweep_points {
                let f = Fabric::new(topo.clone());
                let mut sim = new_sim(&f);
                let rep = sim.run(rebuild_pool.pop().expect("one stream per point"));
                assert_eq!(rep.completed, point_txs.len() as u64);
                black_box(rep.events);
            }
            t0.elapsed().as_nanos() as f64
        };
        let mut master = new_sim(&fabric);
        {
            let rep = master.run(point_txs.clone()); // warm the path arena
            assert_eq!(rep.completed, point_txs.len() as u64);
            master.freeze_paths();
        }
        let mut forked_pool: Vec<Vec<Transaction>> =
            (0..sweep_points).map(|_| point_txs.clone()).collect();
        let forked_wall = {
            let t0 = Instant::now();
            for _ in 0..sweep_points {
                let mut sim = master.fork();
                let rep = sim.run(forked_pool.pop().expect("one stream per point"));
                assert_eq!(rep.completed, point_txs.len() as u64);
                black_box(rep.events);
            }
            t0.elapsed().as_nanos() as f64
        };
        let pps_rebuild = sweep_points as f64 / (rebuild_wall / 1e9);
        let pps_forked = sweep_points as f64 / (forked_wall / 1e9);
        let fork_speedup = pps_forked / pps_rebuild;
        // the bar only makes sense where the router build dominates a
        // point; the 73-node rack's build is timer-noise-sized
        if s.leaves >= 16 {
            assert!(
                fork_speedup >= 3.0,
                "{}: forked sweep points {fork_speedup:.2}x rebuild-per-point, below the 3x bar",
                s.name
            );
        }

        let sharded_str = match sharded {
            Some((shards, eps_sh, sp)) => {
                format!(" | sharded x{shards} {:>6.2} M ev/s ({sp:>5.2}x serial)", eps_sh / 1e6)
            }
            None => String::new(),
        };
        println!(
            "{:<5} {:>5} nodes ({cross_hops} cross-fabric hops) | router build {:>9.2} ms (seed {:>9.2} ms, {:>5.2}x; K=4 {:>9.2} ms, {:>4.2}x of single) | memsim {:>6.2} M ev/s (seed {:>6.2}, {:>5.2}x) | sweep {:>7.1} pts/s forked vs {:>7.1} rebuilt ({:>5.2}x){sharded_str}",
            s.name,
            n_nodes,
            build_new / 1e6,
            build_seed / 1e6,
            build_speedup,
            build_multi / 1e6,
            build_multi_ratio,
            eps_new / 1e6,
            eps_seed / 1e6,
            sim_speedup,
            pps_forked,
            pps_rebuild,
            fork_speedup,
        );
        if let Some((shards, eps_ser, eps_sh, sp)) = reactive {
            println!(
                "{:<5} reactive (per-leaf coherence + rings) | sharded x{shards} {:>6.2} M ev/s vs serial {:>6.2} M ev/s ({sp:>5.2}x)",
                s.name,
                eps_sh / 1e6,
                eps_ser / 1e6,
            );
        }
        if let Some((shards, eps_ser, eps_sh, sp, ckpts, rbs)) = optimistic {
            println!(
                "{:<5} optimistic (global ring + per-leaf coherence) | sharded x{shards} {:>6.2} M ev/s vs serial {:>6.2} M ev/s ({sp:>5.2}x) | {ckpts} checkpoints, {rbs} rollbacks",
                s.name,
                eps_sh / 1e6,
                eps_ser / 1e6,
            );
        }
        println!(
            "{:<5} flight recorder armed | {:>6.2} M ev/s ({:.2}x of untraced)",
            s.name,
            eps_traced / 1e6,
            trace_overhead_ratio,
        );
        if let Some((eps_f, eps_u, sp, hops, rate)) = fused {
            println!(
                "{:<5} express dispatch (sparse open-loop) | fused {:>6.2} M ev/s vs unfused {:>6.2} M ev/s ({sp:>5.2}x) | {hops} hops fused, rate {rate:.2}",
                s.name,
                eps_f / 1e6,
                eps_u / 1e6,
            );
        }

        let mut row = vec![
            ("scale", Json::str(s.name)),
            ("nodes", Json::num(n_nodes as f64)),
            ("cross_fabric_hops", Json::num(cross_hops as f64)),
            ("endpoints", Json::num(eps.len() as f64)),
            ("transactions", Json::num(txs.len() as f64)),
            ("router_build_ms", Json::num(build_new / 1e6)),
            ("router_build_seed_ms", Json::num(build_seed / 1e6)),
            ("router_build_speedup", Json::num(build_speedup)),
            ("router_build_multipath_ms", Json::num(build_multi / 1e6)),
            ("router_build_multipath_ratio", Json::num(build_multi_ratio)),
            ("memsim_events_per_sec", Json::num(eps_new)),
            ("memsim_events_per_sec_seed", Json::num(eps_seed)),
            ("memsim_speedup", Json::num(sim_speedup)),
            ("traced_events_per_sec", Json::num(eps_traced)),
            ("trace_overhead_ratio", Json::num(trace_overhead_ratio)),
            ("sweep_points", Json::num(sweep_points as f64)),
            ("sweep_point_transactions", Json::num(point_txs.len() as f64)),
            ("sweep_points_per_sec", Json::num(pps_forked)),
            ("sweep_points_per_sec_rebuild", Json::num(pps_rebuild)),
            ("sweep_fork_speedup", Json::num(fork_speedup)),
        ];
        if let Some((shards, eps_sh, sp)) = sharded {
            row.push(("sharded_shards", Json::num(shards as f64)));
            row.push(("sharded_events_per_sec", Json::num(eps_sh)));
            row.push(("sharded_speedup", Json::num(sp)));
        }
        if let Some((shards, eps_ser, eps_sh, sp)) = reactive {
            row.push(("reactive_sharded_shards", Json::num(shards as f64)));
            row.push(("reactive_serial_events_per_sec", Json::num(eps_ser)));
            row.push(("reactive_sharded_events_per_sec", Json::num(eps_sh)));
            row.push(("reactive_sharded_speedup", Json::num(sp)));
        }
        if let Some((eps_f, eps_u, sp, hops, rate)) = fused {
            row.push(("fused_events_per_sec", Json::num(eps_f)));
            row.push(("unfused_events_per_sec", Json::num(eps_u)));
            row.push(("fused_speedup", Json::num(sp)));
            row.push(("fused_hops", Json::num(hops as f64)));
            row.push(("fusion_rate", Json::num(rate)));
        }
        if let Some((shards, eps_ser, eps_sh, sp, ckpts, rbs)) = optimistic {
            row.push(("optimistic_sharded_shards", Json::num(shards as f64)));
            row.push(("optimistic_serial_events_per_sec", Json::num(eps_ser)));
            row.push(("optimistic_events_per_sec", Json::num(eps_sh)));
            row.push(("optimistic_speedup", Json::num(sp)));
            row.push(("optimistic_checkpoints", Json::num(ckpts as f64)));
            row.push(("optimistic_rollbacks", Json::num(rbs as f64)));
        }
        rows.push(Json::obj(row));
    }

    // --- raw engine throughput: calendar queue vs seed-style heap ----------
    let engine_events = 1_000_000usize;
    let slab_ns = best_of(3, || {
        let mut e = Engine::new();
        // rolling window of 1024 pending events, like a live simulation
        for i in 0..1024u64 {
            e.schedule(i as f64, EventKind::Custom { tag: i });
        }
        let mut fired = 0usize;
        while fired < engine_events {
            let (now, _) = e.next().unwrap();
            e.schedule(now + 1024.0, EventKind::Custom { tag: 0 });
            fired += 1;
        }
        fired
    });
    let seed_heap_ns = best_of(3, || {
        let mut e = SeedEngine::default();
        for i in 0..1024u64 {
            e.schedule(i as f64, EventKind::Custom { tag: i });
        }
        let mut fired = 0usize;
        while fired < engine_events {
            let (now, _) = e.next().unwrap();
            e.schedule(now + 1024.0, EventKind::Custom { tag: 0 });
            fired += 1;
        }
        fired
    });
    let engine_new = engine_events as f64 / (slab_ns / 1e9);
    let engine_seed = engine_events as f64 / (seed_heap_ns / 1e9);
    println!(
        "engine schedule+dispatch: {:.2} M ev/s calendar vs {:.2} M ev/s seed heap ({:.2}x)",
        engine_new / 1e6,
        engine_seed / 1e6,
        engine_new / engine_seed
    );

    let out = Json::obj(vec![
        ("bench", Json::str("simscale")),
        ("generated_by", Json::str("rust/benches/simscale.rs")),
        ("threads", Json::num(threads as f64)),
        ("scales", Json::Arr(rows)),
        (
            "engine",
            Json::obj(vec![
                ("calendar_events_per_sec", Json::num(engine_new)),
                ("seed_heap_events_per_sec", Json::num(engine_seed)),
                ("speedup", Json::num(engine_new / engine_seed)),
            ]),
        ),
    ]);
    let path = std::env::var("SCALEPOOL_BENCH_OUT").unwrap_or_else(|_| "BENCH_simscale.json".into());
    std::fs::write(&path, out.to_string()).expect("writing bench output");
    println!("wrote {path}");

    // machine-readable summary line (consumed by EXPERIMENTS.md tooling)
    let pod = rows_summary(&out);
    println!("RESULT simscale {pod}");
}

fn rows_summary(out: &Json) -> String {
    let scales = out.get("scales").and_then(Json::as_arr).unwrap_or(&[]);
    let pod = scales.iter().find(|r| r.get("scale").and_then(Json::as_str) == Some("pod"));
    match pod {
        Some(p) => {
            let mut s = format!(
                "pod_router_build_speedup={:.2} pod_memsim_speedup={:.2}",
                p.get("router_build_speedup").and_then(Json::as_f64).unwrap_or(0.0),
                p.get("memsim_speedup").and_then(Json::as_f64).unwrap_or(0.0)
            );
            if let Some(sp) = p.get("sharded_speedup").and_then(Json::as_f64) {
                s.push_str(&format!(" pod_sharded_speedup={sp:.2}"));
            }
            if let Some(sp) = p.get("reactive_sharded_speedup").and_then(Json::as_f64) {
                s.push_str(&format!(" pod_reactive_sharded_speedup={sp:.2}"));
            }
            if let Some(sp) = p.get("optimistic_speedup").and_then(Json::as_f64) {
                s.push_str(&format!(" pod_optimistic_speedup={sp:.2}"));
            }
            if let Some(sp) = p.get("sweep_fork_speedup").and_then(Json::as_f64) {
                s.push_str(&format!(" pod_sweep_fork_speedup={sp:.2}"));
            }
            if let Some(sp) = p.get("fused_speedup").and_then(Json::as_f64) {
                s.push_str(&format!(" pod_fused_speedup={sp:.2}"));
            }
            // advisory: the fraction of hop-level events express chains
            // admitted inline on the sparse workload
            if let Some(r) = p.get("fusion_rate").and_then(Json::as_f64) {
                s.push_str(&format!(" pod_fusion_rate={r:.2}"));
            }
            // advisory (not a *_speedup key): recording cost when armed
            if let Some(r) = p.get("trace_overhead_ratio").and_then(Json::as_f64) {
                s.push_str(&format!(" pod_trace_overhead_ratio={r:.2}"));
            }
            s
        }
        None => "no pod row".into(),
    }
}
