//! Bench F6 — regenerates Figure 6 (LLM training time, ScalePool vs RDMA
//! baseline, five workloads, breakdown) and times the estimator itself,
//! plus ablations over the design choices DESIGN.md calls out.
//!
//! Run with: `cargo bench --bench fig6_llm_training`

use scalepool::bench::{BenchConfig, BenchGroup};
use scalepool::calculon::execution::SystemProfile;
use scalepool::calculon::presets::paper_workloads;
use scalepool::experiments::fig6;

fn main() {
    // --- the figure itself ------------------------------------------------
    let res = fig6::run_fig6();
    print!("{}", fig6::render(&res));

    // --- ablations ---------------------------------------------------------
    println!("\nablation: what the CXL fabric's properties each contribute");
    let base = SystemProfile::baseline_rdma();
    let pool = SystemProfile::scalepool_cxl();

    // (a) CXL wires but RDMA-style software on top (no hardware coherence)
    let mut sw_on_cxl = pool.clone();
    sw_on_cxl.inter_rack.sw_overhead_ns = base.inter_rack.sw_overhead_ns;
    sw_on_cxl.inter_rack.bw_efficiency = base.inter_rack.bw_efficiency;
    let a = fig6::run_fig6_with(base.clone(), sw_on_cxl, &paper_workloads());
    println!("  CXL wires + RDMA software:   avg speedup {:.2}x (hardware path is the point, not the wires)", a.avg_speedup());

    // (b) RDMA wires but zero software overhead (idealized NIC offload)
    let mut hw_on_ib = base.clone();
    hw_on_ib.inter_rack.sw_overhead_ns = pool.inter_rack.sw_overhead_ns;
    hw_on_ib.inter_rack.bw_efficiency = pool.inter_rack.bw_efficiency;
    let b = fig6::run_fig6_with(base.clone(), hw_on_ib, &paper_workloads());
    println!("  IB wires + CXL-like software: avg speedup {:.2}x", b.avg_speedup());

    // (c) full ScalePool
    println!("  full ScalePool:               avg speedup {:.2}x", res.avg_speedup());

    // --- estimator micro-bench ---------------------------------------------
    let mut g = BenchGroup::new("fig6 estimator hot path").with_config(BenchConfig { warmup_iters: 5, iters: 50 });
    g.bench("estimate 5 workloads x 2 systems", fig6::run_fig6);

    // machine-readable summary line (consumed by EXPERIMENTS.md tooling)
    println!(
        "\nRESULT fig6 avg_speedup={:.3} max_speedup={:.3} comm_speedup={:.3}",
        res.avg_speedup(),
        res.max_speedup(),
        res.avg_comm_speedup()
    );
}
