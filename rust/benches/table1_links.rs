//! Bench T1 — regenerates Table 1 from the link models and benchmarks the
//! per-link latency model across message sizes (the numbers behind the
//! table's latency column).
//!
//! Run with: `cargo bench --bench table1_links`

use scalepool::bench::{BenchConfig, BenchGroup};
use scalepool::experiments::table1;
use scalepool::fabric::LinkKind;

fn main() {
    let rows = table1::run_table1();
    print!("{}", table1::render(&rows));

    // per-link message-latency curves (the model behind the table)
    println!("\nmessage latency by size (one link, one way):");
    let kinds = [
        LinkKind::NvLink5,
        LinkKind::UaLink,
        LinkKind::CxlCoherent,
        LinkKind::CxlCapacity,
        LinkKind::PcieGen5,
        LinkKind::InfiniBandNdr,
    ];
    print!("{:>28}", "bytes");
    for k in kinds {
        print!("{:>14}", k.name().split_whitespace().next().unwrap());
    }
    println!();
    for bytes in [64.0, 256.0, 4096.0, 65536.0, 1048576.0] {
        print!("{bytes:>28}");
        for k in kinds {
            print!("{:>12.0}ns", k.params().message_latency_ns(bytes));
        }
        println!();
    }

    // packetization efficiency (the flit-size story of §2)
    println!("\npacketization efficiency (payload/wire) at 64 B vs 64 KiB:");
    for k in kinds {
        let p = k.params();
        println!(
            "  {:<28} {:.2} -> {:.2}",
            k.name(),
            p.flit.efficiency(64.0),
            p.flit.efficiency(65536.0)
        );
    }

    let mut g = BenchGroup::new("link model hot path").with_config(BenchConfig { warmup_iters: 10, iters: 100 });
    g.bench("message_latency_ns x 6 links x 5 sizes", || {
        let mut acc = 0.0;
        for k in kinds {
            let p = k.params();
            for b in [64.0, 256.0, 4096.0, 65536.0, 1048576.0] {
                acc += p.message_latency_ns(b);
            }
        }
        acc
    });
}
