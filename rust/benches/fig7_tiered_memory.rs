//! Bench F7 — regenerates Figure 7 (tiered-memory latency vs working-set
//! size, three configurations) plus a coherence-protocol ablation and the
//! sweep's own timing.
//!
//! Run with: `cargo bench --bench fig7_tiered_memory`

use scalepool::bench::{BenchConfig, BenchGroup};
use scalepool::coherence::Directory;
use scalepool::experiments::fig7;
use scalepool::util::Rng;

fn main() {
    let rows = fig7::run_fig7();
    print!("{}", fig7::render(&rows));

    let r2 = rows.iter().find(|r| r.working_set == 16.0 * fig7::ACCEL_HBM).unwrap();
    let r3 = rows.iter().find(|r| r.working_set == 8.0 * fig7::CLUSTER_HBM).unwrap();

    // --- ablation: coherence traffic cost of tier-1 sharing ---------------
    // measure protocol messages per access for a sharing-heavy pattern —
    // the cost the paper's "selective coherence" (§5) avoids paying for
    // data that does not need it
    let mut dir = Directory::new(8);
    let mut rng = Rng::new(11);
    let mut msgs = 0u64;
    let accesses = 100_000;
    for _ in 0..accesses {
        let agent = rng.below(8) as usize;
        let block = rng.zipf(10_000, 0.9);
        let m = if rng.f64() < 0.3 { dir.write(agent, block) } else { dir.read(agent, block) };
        msgs += m.total() as u64;
    }
    dir.check_invariants().unwrap();
    println!(
        "\ncoherence ablation: {:.2} protocol messages/access on a zipf share-heavy pattern ({} c2c, {} invalidations)",
        msgs as f64 / accesses as f64,
        dir.stats().cache_to_cache,
        dir.stats().invalidations
    );

    // --- sweep timing -------------------------------------------------------
    let mut g = BenchGroup::new("fig7 sweep hot path").with_config(BenchConfig { warmup_iters: 3, iters: 30 });
    let p = fig7::Fig7Params::reference();
    g.bench("10-point analytic sweep (3 configs)", || fig7::run_fig7_with(&p));
    g.bench("fabric-derived params (topology build + routing)", fig7::Fig7Params::reference);

    println!(
        "\nRESULT fig7 region2_speedup={:.3} region3_vs_baseline={:.3} region3_vs_acc_clusters={:.3}",
        r2.speedup_vs_baseline(),
        r3.speedup_vs_baseline(),
        r3.speedup_vs_acc_clusters()
    );
}
