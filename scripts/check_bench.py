#!/usr/bin/env python3
"""Enforce the perf-trajectory floor on BENCH_simscale.json.

Every recorded ``*_speedup`` (and the engine section's ``speedup``) must
stay >= 1.0: the optimized paths are never allowed to regress below their
seed/serial baselines. The sharded backend's speedup is only *enforced*
when the recording machine had >= 4 cores (its acceptance bar is defined
at >= 4 cores; on narrower machines it is reported but advisory).

Usage: check_bench.py [BENCH_simscale.json]
"""

import json
import sys

FLOOR = 1.0
SHARDED_MIN_THREADS = 4


def walk(node, path, out):
    if isinstance(node, dict):
        for k, v in node.items():
            if isinstance(v, (int, float)) and (k.endswith("_speedup") or k == "speedup"):
                out.append((f"{path}.{k}" if path else k, k, float(v)))
            else:
                walk(v, f"{path}.{k}" if path else k, out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            walk(v, f"{path}[{i}]", out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_simscale.json"
    how_to_record = (
        "record it first with scripts/bench.sh, or directly:\n"
        f"  SCALEPOOL_BENCH_OUT={path} cargo bench "
        "--manifest-path rust/Cargo.toml --bench simscale\n"
        "(bounded run: prefix with SCALEPOOL_BENCH_SCALES=rack "
        "SCALEPOOL_BENCH_ACCESSES=60000)"
    )
    try:
        with open(path) as f:
            raw = f.read()
    except FileNotFoundError:
        print(f"error: {path} not found — the bench has never been run here;\n{how_to_record}", file=sys.stderr)
        return 1
    if not raw.strip():
        print(f"error: {path} is empty — the bench run did not record anything;\n{how_to_record}", file=sys.stderr)
        return 1
    try:
        data = json.loads(raw)
    except json.JSONDecodeError as e:
        print(f"error: {path} is not valid JSON ({e}) — likely a truncated bench run;\n{how_to_record}", file=sys.stderr)
        return 1
    if not data:
        print(f"error: {path} holds no measurements;\n{how_to_record}", file=sys.stderr)
        return 1
    threads = int(data.get("threads", 1))
    speedups = []
    walk(data, "", speedups)
    if not speedups:
        print(f"error: no *_speedup entries found in {path}", file=sys.stderr)
        return 1
    failures = []
    for where, key, value in speedups:
        advisory = key.startswith("sharded") and threads < SHARDED_MIN_THREADS
        status = "ok" if value >= FLOOR else ("advisory" if advisory else "FAIL")
        print(f"{status:>8}  {where} = {value:.2f}")
        if value < FLOOR and not advisory:
            failures.append((where, value))
    if failures:
        print(f"\nerror: {len(failures)} speedup(s) below the {FLOOR}x floor:", file=sys.stderr)
        for where, value in failures:
            print(f"  {where} = {value:.2f}", file=sys.stderr)
        return 1
    advisories = sum(1 for _, k, v in speedups if v < FLOOR and k.startswith("sharded"))
    note = f", {advisories} advisory below floor" if advisories else ""
    print(f"\n{len(speedups)} recorded speedups checked, none below the {FLOOR}x floor{note} (threads={threads})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
