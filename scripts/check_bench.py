#!/usr/bin/env python3
"""Enforce the perf-trajectory floor on BENCH_simscale.json.

Every recorded ``*_speedup`` (and the engine section's ``speedup``) must
stay >= 1.0: the optimized paths are never allowed to regress below their
seed/serial baselines. The sharded backend's speedup is only *enforced*
when the recording machine had >= 4 cores (its acceptance bar is defined
at >= 4 cores; on narrower machines it is reported but advisory).

Multi-rail routing points (``rails``/``rails_*`` entries, recorded by
scripts/bench.sh into BENCH_figs.json) are *advisory*: they carry no
speedup bar — inflation, path-diversity and imbalance metrics are
trajectory data, not floors — and unknown keys in them are never an
error. Pointing this checker at a figure-level record (e.g.
BENCH_figs.json) lists its entries and exits 0 instead of tracebacking
on the unfamiliar shape.

Usage: check_bench.py [BENCH_simscale.json]
"""

import json
import sys

FLOOR = 1.0
SHARDED_MIN_THREADS = 4


def walk(node, path, out):
    if isinstance(node, dict):
        for k, v in node.items():
            if isinstance(v, (int, float)) and (k.endswith("_speedup") or k == "speedup"):
                out.append((f"{path}.{k}" if path else k, k, float(v)))
            else:
                walk(v, f"{path}.{k}" if path else k, out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            walk(v, f"{path}[{i}]", out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_simscale.json"
    how_to_record = (
        "record it first with scripts/bench.sh, or directly:\n"
        f"  SCALEPOOL_BENCH_OUT={path} cargo bench "
        "--manifest-path rust/Cargo.toml --bench simscale\n"
        "(bounded run: prefix with SCALEPOOL_BENCH_SCALES=rack "
        "SCALEPOOL_BENCH_ACCESSES=60000)"
    )
    try:
        with open(path) as f:
            raw = f.read()
    except FileNotFoundError:
        print(f"error: {path} not found — the bench has never been run here;\n{how_to_record}", file=sys.stderr)
        return 1
    if not raw.strip():
        print(f"error: {path} is empty — the bench run did not record anything;\n{how_to_record}", file=sys.stderr)
        return 1
    try:
        data = json.loads(raw)
    except json.JSONDecodeError as e:
        print(f"error: {path} is not valid JSON ({e}) — likely a truncated bench run;\n{how_to_record}", file=sys.stderr)
        return 1
    if not data:
        print(f"error: {path} holds no measurements;\n{how_to_record}", file=sys.stderr)
        return 1
    if isinstance(data, list):
        # experiment --out dumps (e.g. `scalepool rails --out`) are
        # top-level arrays of policy points: advisory, no speedup bar
        print(f"{path}: list-shaped experiment record ({len(data)} entries) — advisory, no speedup bar to enforce")
        return 0
    threads = int(data.get("threads", 1))
    speedups = []
    walk(data, "", speedups)
    if not speedups:
        # figure-level records (BENCH_figs.json): mixed / qos_* / rails_*
        # policy points are advisory trajectory data with no speedup bar —
        # list them instead of erroring on the unfamiliar keys
        names = sorted(data) if isinstance(data, dict) else []
        if any(n.startswith(("mixed", "qos", "rails", "fig")) for n in names):
            print(
                f"{path}: figure-level record ({', '.join(names)}) — "
                "advisory trajectory data, no speedup bar to enforce"
            )
            return 0
        print(f"error: no *_speedup entries found in {path}", file=sys.stderr)
        return 1
    failures = []
    for where, key, value in speedups:
        advisory = (key.startswith("sharded") and threads < SHARDED_MIN_THREADS) or (
            # rails policy points ride along in merged records: advisory
            "rails" in where
        )
        status = "ok" if value >= FLOOR else ("advisory" if advisory else "FAIL")
        print(f"{status:>8}  {where} = {value:.2f}")
        if value < FLOOR and not advisory:
            failures.append((where, value))
    if failures:
        print(f"\nerror: {len(failures)} speedup(s) below the {FLOOR}x floor:", file=sys.stderr)
        for where, value in failures:
            print(f"  {where} = {value:.2f}", file=sys.stderr)
        return 1
    advisories = sum(1 for _, k, v in speedups if v < FLOOR and k.startswith("sharded"))
    note = f", {advisories} advisory below floor" if advisories else ""
    print(f"\n{len(speedups)} recorded speedups checked, none below the {FLOOR}x floor{note} (threads={threads})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
