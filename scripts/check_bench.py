#!/usr/bin/env python3
"""Enforce the perf-trajectory floor on BENCH_simscale.json.

Every recorded ``*_speedup`` (and the engine section's ``speedup``) must
stay >= 1.0: the optimized paths are never allowed to regress below their
seed/serial baselines. The sharded backend's speedup is only *enforced*
when the recording machine had >= 4 cores (its acceptance bar is defined
at >= 4 cores; on narrower machines it is reported but advisory).

Express-dispatch entries (ISSUE 10): ``fused_speedup`` is floor-checked
and regression-gated like any other ``*_speedup`` key, except at rack
scale where the single-crossbar 2-hop paths leave one fusible hop per
transaction and the ratio is timer-noise-sized (advisory there; the
bench itself asserts the >= 1.5x bar at pod scale).
``fused_events_per_sec`` and ``fusion_rate`` carry no floor by
construction (not ``*_speedup`` keys); the fusion rate is echoed as an
advisory line so trajectory regressions stay visible. Records made with
``SCALEPOOL_BENCH_FUSION=off`` simply omit the fused keys.

Multi-rail routing points (``rails``/``rails_*`` entries, recorded by
scripts/bench.sh into BENCH_figs.json) are *advisory*: they carry no
speedup bar — inflation, path-diversity and imbalance metrics are
trajectory data, not floors — and unknown keys in them are never an
error. Pointing this checker at a figure-level record (e.g.
BENCH_figs.json) lists its entries and exits 0 instead of tracebacking
on the unfamiliar shape.

With ``--baseline OLD.json`` the fresh record is additionally compared
against a previously committed one: any speedup present in both that
falls below ``0.9x`` its baseline value (a >10% regression) fails,
unless that entry is advisory. Entries present in only one record are
reported but never an error — scales and keys grow over time.

Usage: check_bench.py [BENCH_simscale.json] [--baseline OLD.json]
"""

import json
import sys

FLOOR = 1.0
REGRESSION_RATIO = 0.9
SHARDED_MIN_THREADS = 4


def walk(node, path, out, scale=None):
    if isinstance(node, dict):
        if isinstance(node.get("scale"), str):
            scale = node["scale"]
        for k, v in node.items():
            if isinstance(v, (int, float)) and (k.endswith("_speedup") or k == "speedup"):
                out.append((f"{path}.{k}" if path else k, k, float(v), scale))
            else:
                walk(v, f"{path}.{k}" if path else k, out, scale)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            walk(v, f"{path}[{i}]", out, scale)


def is_advisory(where, key, scale, threads):
    if key.startswith(("sharded", "reactive_sharded", "optimistic")) and threads < SHARDED_MIN_THREADS:
        # sharded acceptance bars (batch, reactive and optimistic) are
        # defined at >= 4 cores; below that the speedup is reported but
        # advisory
        return True
    if "rails" in where:
        # rails policy points ride along in merged records: advisory
        return True
    if key == "sweep_fork_speedup" and scale == "rack":
        # a rack (single-crossbar) build is sub-millisecond, so the
        # fork-vs-rebuild ratio there is timer noise; the >= 3x bar is
        # asserted by the bench itself at row scale and beyond
        return True
    if key == "fused_speedup" and scale == "rack":
        # rack's 2-hop paths leave a single fusible hop per transaction,
        # so the wall-time margin is runner noise; the >= 1.5x bar is
        # asserted by the bench itself at pod scale
        return True
    return False


def walk_key(node, want, path, out, scale=None):
    """Collect every numeric ``want`` key (advisory metrics without a
    speedup bar, e.g. ``fusion_rate``) with its record path and scale."""
    if isinstance(node, dict):
        if isinstance(node.get("scale"), str):
            scale = node["scale"]
        for k, v in node.items():
            if k == want and isinstance(v, (int, float)):
                out.append((f"{path}.{k}" if path else k, float(v), scale))
            else:
                walk_key(v, want, f"{path}.{k}" if path else k, out, scale)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            walk_key(v, want, f"{path}[{i}]", out, scale)


def main():
    argv = sys.argv[1:]
    baseline_path = None
    if "--baseline" in argv:
        i = argv.index("--baseline")
        if i + 1 >= len(argv):
            print("error: --baseline needs a path argument", file=sys.stderr)
            return 2
        baseline_path = argv[i + 1]
        del argv[i : i + 2]
    path = argv[0] if argv else "BENCH_simscale.json"
    how_to_record = (
        "record it first with scripts/bench.sh, or directly:\n"
        f"  SCALEPOOL_BENCH_OUT={path} cargo bench "
        "--manifest-path rust/Cargo.toml --bench simscale\n"
        "(bounded run: prefix with SCALEPOOL_BENCH_SCALES=rack "
        "SCALEPOOL_BENCH_ACCESSES=60000)"
    )
    try:
        with open(path) as f:
            raw = f.read()
    except FileNotFoundError:
        print(f"error: {path} not found — the bench has never been run here;\n{how_to_record}", file=sys.stderr)
        return 1
    if not raw.strip():
        print(f"error: {path} is empty — the bench run did not record anything;\n{how_to_record}", file=sys.stderr)
        return 1
    try:
        data = json.loads(raw)
    except json.JSONDecodeError as e:
        print(f"error: {path} is not valid JSON ({e}) — likely a truncated bench run;\n{how_to_record}", file=sys.stderr)
        return 1
    if not data:
        print(f"error: {path} holds no measurements;\n{how_to_record}", file=sys.stderr)
        return 1
    if isinstance(data, list):
        # experiment --out dumps (e.g. `scalepool rails --out`) are
        # top-level arrays of policy points: advisory, no speedup bar
        print(f"{path}: list-shaped experiment record ({len(data)} entries) — advisory, no speedup bar to enforce")
        return 0
    threads = int(data.get("threads", 1))
    speedups = []
    walk(data, "", speedups)
    if not speedups:
        # figure-level records (BENCH_figs.json): mixed / qos_* / rails_*
        # policy points are advisory trajectory data with no speedup bar —
        # list them instead of erroring on the unfamiliar keys
        names = sorted(data) if isinstance(data, dict) else []
        if any(n.startswith(("mixed", "qos", "rails", "fig")) for n in names):
            print(
                f"{path}: figure-level record ({', '.join(names)}) — "
                "advisory trajectory data, no speedup bar to enforce"
            )
            return 0
        print(f"error: no *_speedup entries found in {path}", file=sys.stderr)
        return 1
    failures = []
    advisories = 0
    for where, key, value, scale in speedups:
        advisory = is_advisory(where, key, scale, threads)
        status = "ok" if value >= FLOOR else ("advisory" if advisory else "FAIL")
        print(f"{status:>8}  {where} = {value:.2f}")
        if value < FLOOR:
            if advisory:
                advisories += 1
            else:
                failures.append((where, value, f"below the {FLOOR}x floor"))
    # advisory echo: express-dispatch fusion rate (no floor here — the
    # >= 0.5 bar on the sparse workload is asserted in-bench at pod scale)
    rates = []
    walk_key(data, "fusion_rate", "", rates)
    for where, value, _ in rates:
        print(f"advisory  {where} = {value:.2f} (fusion rate; in-bench bar)")
    if baseline_path is not None:
        try:
            with open(baseline_path) as f:
                base = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError) as e:
            print(f"error: baseline {baseline_path} unusable ({e})", file=sys.stderr)
            return 1
        base_speedups = []
        walk(base, "", base_speedups)
        base_by_path = {w: v for w, _, v, _ in base_speedups}
        print(f"\nregression gate vs {baseline_path} (fail below {REGRESSION_RATIO}x baseline):")
        compared = 0
        for where, key, value, scale in speedups:
            if where not in base_by_path:
                print(f"     new  {where} = {value:.2f} (not in baseline)")
                continue
            compared += 1
            bar = base_by_path[where] * REGRESSION_RATIO
            advisory = is_advisory(where, key, scale, threads)
            ok = value >= bar
            status = "ok" if ok else ("advisory" if advisory else "FAIL")
            print(f"{status:>8}  {where} = {value:.2f} (baseline {base_by_path[where]:.2f}, bar {bar:.2f})")
            if not ok:
                if advisory:
                    advisories += 1
                else:
                    failures.append((where, value, f"regressed >10% vs baseline {base_by_path[where]:.2f}"))
        dropped = sorted(set(base_by_path) - {w for w, _, _, _ in speedups})
        for where in dropped:
            # a scale absent from a bounded run (SCALEPOOL_BENCH_SCALES)
            # is expected; only full runs cover every baseline entry
            print(f" skipped  {where} (baseline-only, not in this run)")
        print(f"  {compared} matched speedup(s) compared against baseline")
    if failures:
        print(f"\nerror: {len(failures)} speedup check(s) failed:", file=sys.stderr)
        for where, value, why in failures:
            print(f"  {where} = {value:.2f} ({why})", file=sys.stderr)
        return 1
    note = f", {advisories} advisory miss(es)" if advisories else ""
    print(f"\n{len(speedups)} recorded speedups checked, no failures{note} (threads={threads})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
