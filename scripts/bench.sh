#!/usr/bin/env bash
# Run the bench binaries and refresh the BENCH_*.json records at the repo
# root. The simscale bench writes BENCH_simscale.json itself (path via
# SCALEPOOL_BENCH_OUT); the figure benches print RESULT lines that are
# captured into BENCH_figs.json.
#
# Bounded runs (the CI smoke): SCALEPOOL_BENCH_SCALES=rack limits simscale
# to the named scales, SCALEPOOL_BENCH_ACCESSES=N shrinks its workload,
# and SCALEPOOL_BENCH_ONLY=simscale skips the figure/micro benches.
# scripts/check_bench.py then enforces the >= 1.0x floor on every
# recorded *_speedup, and with --baseline OLD.json also fails any
# speedup that regressed >10% vs a previously committed record.
set -euo pipefail

cd "$(dirname "$0")/.."
MANIFEST=rust/Cargo.toml

echo "== simscale (router build + events/sec + sharded trajectory) =="
SCALEPOOL_BENCH_OUT=BENCH_simscale.json \
    cargo bench --manifest-path "$MANIFEST" --bench simscale

if [ "${SCALEPOOL_BENCH_ONLY:-}" = "simscale" ]; then
    echo "SCALEPOOL_BENCH_ONLY=simscale: skipping figure/micro benches"
    exit 0
fi

echo "== figure benches =="
fig_results=$(
    cargo bench --manifest-path "$MANIFEST" --bench fig6_llm_training | tee /dev/stderr | grep '^RESULT' || true
    cargo bench --manifest-path "$MANIFEST" --bench fig7_tiered_memory | tee /dev/stderr | grep '^RESULT' || true
)

echo "== interference trajectory (bounded mixed + qos + rails policy sweeps) =="
# rack-scale bounded runs: the perf trajectory records cross-class
# interference (RESULT mixed ...), what each arbitration policy does to
# it (RESULT qos_<policy> ...), and what multi-rail routing does to it
# (RESULT rails_<policy> ..., incl. path diversity and link-utilization
# imbalance), not just events/sec
MIXED_ARGS="--racks 6 --accels 8 --mem-nodes 4 --coh-ops 1200 --tier-ops 300 --t1-bytes 262144 --bytes 4194304 --repeats 1"
interference_results=$(
    # shellcheck disable=SC2086
    cargo run --release --manifest-path "$MANIFEST" -- mixed $MIXED_ARGS | tee /dev/stderr | grep '^RESULT' || true
    # shellcheck disable=SC2086
    cargo run --release --manifest-path "$MANIFEST" -- qos $MIXED_ARGS | tee /dev/stderr | grep '^RESULT qos_' || true
    # shellcheck disable=SC2086
    cargo run --release --manifest-path "$MANIFEST" -- rails $MIXED_ARGS | tee /dev/stderr | grep '^RESULT rails_' || true
)
fig_results="$fig_results
$interference_results"

# RESULT <name> k=v k=v ... -> {"name": {"k": v, ...}, ...}
# (non-numeric values are skipped: per-(policy,class) qos lines carry
# string keys and are for CI greps, not the JSON record)
python3 - "$fig_results" <<'EOF'
import json, sys
out = {}
for line in sys.argv[1].splitlines():
    parts = line.split()
    if len(parts) < 2 or parts[0] != "RESULT":
        continue
    name, kvs = parts[1], parts[2:]
    row = {}
    for kv in kvs:
        k, _, v = kv.partition("=")
        try:
            row[k] = float(v)
        except ValueError:
            continue
    if row:
        out[name] = row
with open("BENCH_figs.json", "w") as f:
    json.dump(out, f, indent=2)
print("wrote BENCH_figs.json")
EOF

echo "== micro_fabric (informational, no JSON) =="
cargo bench --manifest-path "$MANIFEST" --bench micro_fabric
