#!/usr/bin/env python3
"""Validate Chrome ``trace_event`` JSON emitted by ``scalepool trace``.

Structural checks on the ``traceEvents`` array:

* every event carries the phase-appropriate required fields
  (``B``/``E`` need pid/tid/ts/name; counters ``C`` need pid/ts/name/args;
  instants ``i`` need ts and a scope ``s``);
* per (pid, tid) track, ``B``/``E`` events alternate starting with ``B``
  and ending balanced — the exporter emits complete spans only;
* ``B``/``E`` timestamps are non-decreasing within a track and every
  ``E`` closes at or after its ``B`` (instants and counters share tid 0
  with the lifecycle pass and are exempt from the track ordering rule —
  they are emitted in separate passes);
* optional content requirements: ``--require-class NAME`` asserts at
  least one hop span of that traffic class (hop spans are named after
  their class), ``--require-instant KIND`` asserts at least one instant
  of that name (epoch / checkpoint / rollback / inject / complete).

Exits non-zero with a list of violations; prints a one-line summary on
success.

Usage: check_trace.py TRACE.json [--require-class NAME]...
                                 [--require-instant KIND]...
"""

import json
import sys


def fail(errors):
    for e in errors[:40]:
        print(f"FAIL: {e}")
    if len(errors) > 40:
        print(f"... and {len(errors) - 40} more")
    sys.exit(1)


def main(argv):
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        sys.exit(0)
    path = argv[0]
    want_classes, want_instants = [], []
    i = 1
    while i < len(argv):
        if argv[i] == "--require-class" and i + 1 < len(argv):
            want_classes.append(argv[i + 1])
            i += 2
        elif argv[i] == "--require-instant" and i + 1 < len(argv):
            want_instants.append(argv[i + 1])
            i += 2
        else:
            print(f"unknown argument {argv[i]!r}")
            sys.exit(2)

    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail([f"{path}: no traceEvents array"])

    errors = []
    tracks = {}  # (pid, tid) -> [depth, last_ts, last_b_ts]
    seen_classes, seen_instants = set(), set()
    counts = {"B": 0, "E": 0, "C": 0, "i": 0, "M": 0}

    for n, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None:
            errors.append(f"event {n}: missing ph")
            continue
        counts[ph] = counts.get(ph, 0) + 1
        if ph == "M":  # metadata names the tracks; no ts required
            if "pid" not in ev or "name" not in ev:
                errors.append(f"event {n}: metadata without pid/name")
            continue
        if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
            errors.append(f"event {n} (ph={ph}): missing numeric ts")
            continue
        ts = ev["ts"]
        if ph in ("B", "E"):
            missing = [k for k in ("pid", "tid", "name") if k not in ev]
            if missing:
                errors.append(f"event {n} (ph={ph}): missing {missing}")
                continue
            key = (ev["pid"], ev["tid"])
            depth, last_ts, last_b = tracks.get(key, [0, None, None])
            if last_ts is not None and ts < last_ts:
                errors.append(
                    f"event {n}: track {key} ts {ts} went backwards from {last_ts}"
                )
            if ph == "B":
                if depth != 0:
                    errors.append(f"event {n}: track {key} opened a span inside a span")
                tracks[key] = [depth + 1, ts, ts]
                # hop spans are named after their traffic class
                seen_classes.add(ev["name"])
            else:
                if depth != 1:
                    errors.append(f"event {n}: track {key} E without matching B")
                elif last_b is not None and ts < last_b:
                    errors.append(f"event {n}: track {key} span closes before it opens")
                tracks[key] = [max(depth - 1, 0), ts, None]
        elif ph == "C":
            missing = [k for k in ("pid", "name", "args") if k not in ev]
            if missing:
                errors.append(f"event {n} (ph=C): missing {missing}")
        elif ph == "i":
            if "s" not in ev:
                errors.append(f"event {n} (ph=i): instant without scope s")
            name = ev.get("name", "")
            seen_instants.add(name)
        else:
            errors.append(f"event {n}: unexpected phase {ph!r}")

    for key, (depth, _, _) in tracks.items():
        if depth != 0:
            errors.append(f"track {key}: {depth} unclosed B span(s) at end of trace")
    if counts.get("B", 0) != counts.get("E", 0):
        errors.append(f"unbalanced spans: {counts.get('B', 0)} B vs {counts.get('E', 0)} E")
    for c in want_classes:
        if c not in seen_classes:
            errors.append(f"required class {c!r} has no hop span (saw {sorted(seen_classes)})")
    for k in want_instants:
        if k not in seen_instants:
            errors.append(f"required instant {k!r} absent (saw {sorted(seen_instants)})")

    if errors:
        fail(errors)
    print(
        f"OK: {len(events)} events — {counts.get('B', 0)} spans on {len(tracks)} tracks, "
        f"{counts.get('C', 0)} counter samples, {counts.get('i', 0)} instants"
    )


if __name__ == "__main__":
    main(sys.argv[1:])
