//! Heterogeneous clusters unified by CXL (§4, Figure 3/4b): an NVLink
//! rack of B200s and a UALink rack mixing AMD/Intel/Amazon/Meta
//! accelerators coexist in one ScalePool domain. XLink interoperability
//! rules are enforced; inter-cluster data movement is mediated by CXL.
//!
//! Run with: `cargo run --release --example heterogeneous`

use scalepool::cluster::{
    Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig, XlinkDomain, XlinkError,
};
use scalepool::coordinator::DataMovementRouter;
use scalepool::fabric::{LinkKind, TopologyKind};
use scalepool::util::units::{fmt_bytes, fmt_ns};

fn main() {
    // 1. the interoperability wall: NVLink + UALink cannot share a domain
    let mut nv = XlinkDomain::new(LinkKind::NvLink5);
    nv.add(Accelerator::b200()).unwrap();
    match nv.add(Accelerator::mi300x()) {
        Err(XlinkError::MixedLink(a, b)) => {
            println!("rejected as the paper says it must be: cannot mix {a:?} and {b:?} in one XLink domain")
        }
        other => panic!("expected MixedLink, got {other:?}"),
    }

    // 2. a UALink rack is vendor-neutral
    let mut ua = XlinkDomain::new(LinkKind::UaLink);
    for acc in [
        Accelerator::mi300x(),
        Accelerator::gaudi3(),
        Accelerator::trainium2(),
        Accelerator::mtia2(),
        Accelerator::maia100(),
    ] {
        ua.add(acc).unwrap();
    }
    ua.validate().unwrap();
    println!(
        "UALink rack: {} heterogeneous accelerators, {} HBM, bottleneck XLink bw {:.0} GB/s",
        ua.members.len(),
        fmt_bytes(ua.total_hbm()),
        ua.per_device_bw()
    );

    // 3. both cluster kinds in one ScalePool, abstracted through CXL
    let nv_rack = Rack::homogeneous("nv0", Accelerator::b200(), 8).unwrap();
    let ua_rack = Rack { name: "ua0".into(), domain: ua, cxl_uplinks: 8 };
    let sys = ScalePoolBuilder::new()
        .rack(nv_rack)
        .rack(ua_rack)
        .config(SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: 4,
            ..Default::default()
        })
        .build();
    println!(
        "\nunified domain: {} accelerators ({} + {}), connected: {}",
        sys.accelerator_count(),
        sys.racks[0].acc_ids.len(),
        sys.racks[1].acc_ids.len(),
        sys.fabric.topo.is_connected()
    );

    // 4. inter-cluster data movement paths (Figure 4b): B200 -> MI300X
    //    without InfiniBand and without an NVIDIA-proprietary bridge
    let router = DataMovementRouter::new(&sys);
    for bytes in [64.0, 4096.0, 1048576.0, 134217728.0] {
        let d = router.route(sys.racks[0].acc_ids[0], sys.racks[1].acc_ids[0], bytes);
        println!(
            "  B200 -> MI300X {:>10}: {:?} via {} hops, est {}",
            fmt_bytes(bytes),
            d.class,
            d.hops,
            fmt_ns(d.est_latency_ns)
        );
    }

    // 5. both clusters share the tier-2 pool
    println!(
        "\nshared tier-2 pool {} reachable from both clusters: nv rt {}, ua rt {}",
        fmt_bytes(sys.tier2_capacity()),
        fmt_ns(sys.tier2_rt_ns(0).unwrap()),
        fmt_ns(sys.tier2_rt_ns(1).unwrap())
    );
}
