//! End-to-end validation driver: train a transformer LM through the full
//! three-layer stack — rust coordinator (L3) driving the AOT-compiled JAX
//! model (L2) with Pallas kernels inside (L1) on PJRT — under hybrid
//! emulation of the paper's cluster deployment.
//!
//! Run with:
//!   make artifacts
//!   cargo run --release --example train_e2e -- [preset] [steps]
//! Defaults: preset = small25m, steps = 50. The paper-scale run recorded
//! in EXPERIMENTS.md uses `base100m 300`.

use scalepool::calculon::Parallelism;
use scalepool::coordinator::{EmulatedCluster, TrainJobScheduler};
use scalepool::runtime::{self, Trainer};
use scalepool::util::units::{fmt_bytes, fmt_ns};

fn main() {
    let mut args = std::env::args().skip(1);
    let preset = args.next().unwrap_or_else(|| "small25m".to_string());
    let steps: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(50);

    if !runtime::artifacts_available(&preset) {
        eprintln!("artifacts for '{preset}' not found — run `make artifacts` first");
        std::process::exit(1);
    }

    let dir = runtime::default_artifacts_dir();
    let trainer = Trainer::load(&dir, &preset).expect("load artifacts");
    let m = trainer.manifest().clone();
    println!(
        "loaded {}: {:.1}M params ({} of f32 state), batch {} x seq {}",
        m.preset,
        m.param_count as f64 / 1e6,
        fmt_bytes((m.param_count * 12) as f64),
        m.batch,
        m.seq
    );

    let cluster = EmulatedCluster::for_preset(
        m.vocab,
        768,
        12,
        12,
        m.seq,
        512,
        Parallelism { tp: 8, pp: 4, dp: 16, microbatch: 1 },
    );
    let (be, se) = cluster.estimates();
    println!(
        "emulated deployment (512 GPUs): baseline step {}, ScalePool step {} ({:.2}x)",
        fmt_ns(be.total_ns()),
        fmt_ns(se.total_ns()),
        be.total_ns() / se.total_ns()
    );

    let mut sched = TrainJobScheduler::new(trainer, cluster, 42);
    sched.init(0).expect("init");
    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < steps {
        let chunk = 10.min(steps - done);
        sched.run(chunk).expect("train step");
        done += chunk;
        let last = sched.log().last().unwrap();
        println!(
            "step {:>4}  loss {:.4}  pjrt {}",
            last.step,
            last.loss,
            fmt_ns(last.compute_wall_ns as f64)
        );
    }
    let log = sched.log();
    println!(
        "\n{} steps in {:.1}s; loss {:.4} -> {:.4}; emulated ScalePool speedup {:.2}x",
        steps,
        t0.elapsed().as_secs_f64(),
        log.first().unwrap().loss,
        log.last().unwrap().loss,
        sched.emulated_speedup()
    );
    assert!(
        log.last().unwrap().loss < log.first().unwrap().loss,
        "loss must decrease over the run"
    );
    println!("loss decreased through the full L3->L2->L1 stack: OK");
}
