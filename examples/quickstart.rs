//! Quickstart: build a 4-cluster ScalePool, inspect the hybrid fabric,
//! compose a tier-2 memory pool, and get a one-line training estimate.
//!
//! Run with: `cargo run --release --example quickstart`

use scalepool::calculon::presets::gpt3_175b;
use scalepool::calculon::execution::SystemProfile;
use scalepool::calculon::ExecutionModel;
use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
use scalepool::coordinator::{DataMovementRouter, JobSpec, ScalePoolManager};
use scalepool::fabric::TopologyKind;
use scalepool::util::units::{fmt_bytes, fmt_ns};

fn main() {
    // 1. four NVL72-style racks joined by a 2-level CXL Clos fabric with
    //    eight tier-2 memory nodes (Figure 2 of the paper)
    let sys = ScalePoolBuilder::new()
        .racks((0..4).map(|i| Rack::homogeneous(&format!("rack{i}"), Accelerator::b200(), 8).unwrap()))
        .config(SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: 8,
            ..Default::default()
        })
        .build();

    println!("ScalePool: {} accelerators across {} clusters", sys.accelerator_count(), sys.racks.len());
    println!("  tier-1 per cluster: {}", fmt_bytes(sys.rack_hbm_capacity(0)));
    println!("  tier-2 pool:        {}", fmt_bytes(sys.tier2_capacity()));
    println!("  intra-rack  64 B:   {}", fmt_ns(sys.acc_latency_ns((0, 0), (0, 1), 64.0)));
    println!("  inter-rack  64 B:   {}", fmt_ns(sys.acc_latency_ns((0, 0), (1, 0), 64.0)));
    println!("  tier-2 round trip:  {}", fmt_ns(sys.tier2_rt_ns(0).unwrap()));

    // 2. route some transfers across the hybrid fabric
    let router = DataMovementRouter::new(&sys);
    for (label, src, dst, bytes) in [
        ("tensor exchange (intra-rack, 1 MiB)", sys.racks[0].acc_ids[0], sys.racks[0].acc_ids[1], 1048576.0),
        ("coherent line (inter-rack, 64 B)", sys.racks[0].acc_ids[0], sys.racks[1].acc_ids[0], 64.0),
        ("bulk gradient (inter-rack, 128 MiB)", sys.racks[0].acc_ids[0], sys.racks[1].acc_ids[0], 134217728.0),
        ("tier-2 KV block (16 KiB)", sys.racks[0].acc_ids[0], sys.mem_nodes[0], 16384.0),
    ] {
        let d = router.route(src, dst, bytes);
        println!("  {label:<40} -> {:?}, est {}", d.class, fmt_ns(d.est_latency_ns));
    }

    // 3. admit a job through the coordinator
    let mut mgr = ScalePoolManager::new(&sys);
    let grant = mgr
        .admit(&JobSpec { name: "train-demo".into(), accelerators: 12, pool_bytes: 2e12 })
        .expect("admission");
    println!(
        "  admitted job {:?}: {} rack(s), {} of tier-2 pool",
        grant.job,
        grant.accelerators.len(),
        fmt_bytes(grant.pool_bytes)
    );

    // 4. one-line training estimate: GPT-3 on this architecture vs RDMA
    let w = gpt3_175b();
    let base = ExecutionModel::new(SystemProfile::baseline_rdma()).estimate(&w.model, &w.par);
    let pool = ExecutionModel::new(SystemProfile::scalepool_cxl()).estimate(&w.model, &w.par);
    println!(
        "\nGPT-3 175B step: baseline {} -> ScalePool {} ({:.2}x)",
        fmt_ns(base.total_ns()),
        fmt_ns(pool.total_ns()),
        base.total_ns() / pool.total_ns()
    );
}
