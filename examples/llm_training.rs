//! Figure 6 driver: LLM training execution time for the five paper
//! workloads, ScalePool vs the RDMA baseline, with the full
//! {communication, computation, other} breakdown and normalized bars.
//!
//! Run with: `cargo run --release --example llm_training`

use scalepool::experiments::fig6;

fn main() {
    let res = fig6::run_fig6();
    print!("{}", fig6::render(&res));

    // normalized stacked bars, the paper's Figure 6 layout
    println!("\nnormalized to each baseline (comm | compute | other):");
    for r in &res.rows {
        let [b, s] = r.normalized();
        let bar = |f: (f64, f64, f64)| {
            let w = |x: f64| "#".repeat((x * 40.0).round() as usize);
            format!("{:<12}|{:<22}|{:<4}", w(f.0), w(f.1), w(f.2))
        };
        println!("{:<16} baseline  {} = 1.00", r.name, bar(b));
        println!("{:<16} scalepool {} = {:.2}", "", bar(s), 1.0 / r.speedup());
    }
}
