//! Figure 7 driver: tiered-memory latency sweep in two modes —
//! the analytic model (the paper's sweep) and a detailed discrete-event
//! cross-check of one working-set point on the built fabric.
//!
//! Run with: `cargo run --release --example tiered_memory`

use scalepool::cluster::{Accelerator, InterCluster, Rack, ScalePoolBuilder, SystemConfig};
use scalepool::experiments::fig7;
use scalepool::fabric::TopologyKind;
use scalepool::sim::{MemSim, Transaction};
use scalepool::util::units::fmt_ns;
use scalepool::util::Rng;

fn main() {
    // --- analytic sweep (the paper's Figure 7) ---------------------------
    let rows = fig7::run_fig7();
    print!("{}", fig7::render(&rows));

    let r2 = rows.iter().find(|r| r.working_set == 16.0 * fig7::ACCEL_HBM).unwrap();
    let r3 = rows.iter().find(|r| r.working_set == 8.0 * fig7::CLUSTER_HBM).unwrap();
    println!(
        "\nregion-2 (WS > accelerator): ScalePool {:.2}x vs baseline (paper: 1.4x)",
        r2.speedup_vs_baseline()
    );
    println!(
        "region-3 (WS > cluster):     ScalePool {:.2}x vs baseline (paper: 4.5x), {:.2}x vs accelerator-clusters (paper: 1.6x)",
        r3.speedup_vs_baseline(),
        r3.speedup_vs_acc_clusters()
    );

    // --- event-driven cross-check ---------------------------------------
    // one tier-2-bound point simulated transaction by transaction on the
    // real fabric graph, contention included
    let sys = ScalePoolBuilder::new()
        .racks((0..2).map(|i| Rack::homogeneous(&format!("rack{i}"), Accelerator::b200(), 8).unwrap()))
        .config(SystemConfig {
            inter: InterCluster::Cxl(TopologyKind::MultiLevelClos),
            mem_nodes: 4,
            ..Default::default()
        })
        .build();
    let mut rng = Rng::new(3);
    let mut at = 0.0;
    let txs: Vec<Transaction> = (0..50_000)
        .map(|_| {
            at += rng.exp(1.0 / 100.0);
            Transaction {
                src: sys.racks[0].acc_ids[rng.below(8) as usize],
                dst: sys.mem_nodes[rng.below(4) as usize],
                at,
                bytes: 64.0,
                device_ns: 130.0,
            }
        })
        .collect();
    let mut sim = MemSim::new(&sys.fabric);
    let rep = sim.run(txs);
    println!(
        "\nevent-sim cross-check (64 B tier-2 reads, contention on): mean one-way {}, p-mean x2 = RT {}",
        fmt_ns(rep.latency.mean()),
        fmt_ns(2.0 * rep.latency.mean())
    );
    println!(
        "analytic tier-2 RT used by the sweep: {} (hop-counted, idle fabric)",
        fmt_ns(fig7::Fig7Params::reference().tier2_rt)
    );
}
